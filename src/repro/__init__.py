"""JAX/Pallas reproduction of "Parallel Scan on Ascend AI Accelerators".

A real (non-namespace) package so wheel installs ship every subpackage plus
the ``configs/tuning/*.json`` package data that ``method="auto"`` dispatch
loads via ``importlib.resources``.
"""
__version__ = "0.1.0"
