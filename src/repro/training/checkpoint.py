"""Fault-tolerant checkpointing.

Design (DESIGN.md §5):
  * step-tagged directories ``ckpt_<step>/`` written ATOMICALLY (tmp dir + rename) —
    a crash mid-save can never corrupt the latest checkpoint;
  * every array saved as ``<flat-key>.npy`` plus a ``manifest.json`` carrying shapes,
    dtypes and crc32 checksums — restore verifies integrity and refuses silently
    corrupted files;
  * ``restore(..., shardings=...)`` re-shards on load, so a job may restart on a
    *different* mesh (elastic scaling: 512 -> 256 chips, or CPU debugging);
  * optional async save (background thread) so the train loop only pays for the
    host transfer, not the disk write;
  * retention policy (keep_last) garbage-collects old steps.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

SEP = "::"


def _flatten(tree):
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(f"#{k.idx}")
            else:
                parts.append(str(k))
        flat[SEP.join(parts)] = leaf
    return flat


def _unflatten_into(template, flat):
    leaves, treedef = jax.tree_util.tree_flatten(template)
    tpl_flat = _flatten(template)
    keys = list(tpl_flat.keys())
    assert len(keys) == len(leaves), "template/flat mismatch"
    return treedef.unflatten([flat[k] for k in keys])


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save ----
    def save(self, step: int, tree: Any, *, blocking: bool = False):
        """Snapshot to host, then write (async unless blocking)."""
        self.wait()                                     # one in-flight save max
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save and not blocking:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host_tree):
        flat = _flatten(host_tree)
        tmp = os.path.join(self.dir, f".tmp_ckpt_{step}")
        final = os.path.join(self.dir, f"ckpt_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "arrays": {}}
        for key, arr in flat.items():
            fname = f"{hashlib.sha1(key.encode()).hexdigest()[:16]}.npy"
            path = os.path.join(tmp, fname)
            np.save(path, arr)
            manifest["arrays"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                           # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"ckpt_{s}"), ignore_errors=True)

    # ---- restore ----
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any, *, shardings=None) -> Any:
        """Load + verify + (re)shard.  ``shardings``: pytree like template or None."""
        d = os.path.join(self.dir, f"ckpt_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for key, meta in manifest["arrays"].items():
            arr = np.load(os.path.join(d, meta["file"]))
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption detected for {key!r} "
                              f"(crc {crc:#x} != {meta['crc32']:#x})")
            flat[key] = arr
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s, t: jax.device_put(np.asarray(x).astype(t.dtype), s),
                tree, shardings, template)
        else:
            tree = jax.tree.map(lambda x, t: jax.device_put(
                np.asarray(x).astype(t.dtype)), tree, template)
        return tree
