"""AdamW (explicit pytree implementation) with mixed-precision state handling.

States are fp32; parameters may be bf16 or fp32 (updates computed in fp32 and cast
back).  With ``zero_over`` set, first/second moments are sharded over the data axis
in addition to the parameter's own sharding (ZeRO-1 style) — wired up by the Trainer
via ``opt_state_specs``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(F32) if hasattr(step, "astype") else jnp.asarray(step, F32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return {"mu": zeros,
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(F32)
    b2c = 1 - cfg.b2 ** step.astype(F32)

    def upd(g, m, v, p):
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                                  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(F32)
        newp = (p.astype(F32) - lr * delta).astype(p.dtype)
        return newp, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["mu"])
    flat_v = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, metrics


def opt_state_specs(param_specs, *, zero_axis: Optional[str] = None):
    """PartitionSpecs for opt state; optionally ZeRO-shard moments over ``zero_axis``
    along the first dimension that is unsharded in the param spec."""
    def moment_spec(ps):
        if zero_axis is None:
            return ps
        parts = list(ps)
        for i, a in enumerate(parts):
            if a is None:
                parts[i] = zero_axis
                return P(*parts)
        return ps
    mu = jax.tree.map(moment_spec, param_specs, is_leaf=lambda x: isinstance(x, P))
    return {"mu": mu, "nu": mu, "step": P()}
