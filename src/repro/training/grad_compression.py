"""int8 gradient all-reduce with error feedback (distributed-optimization trick).

Data-parallel gradient synchronisation normally moves fp32/bf16 over the ICI.  Here
each shard quantises its local gradient to int8 (per-tensor absmax scaling), the
all-reduce runs on int8 payloads accumulated in int32 (the same int8→int32 cube-unit
path the paper exploits for mask scans, now applied to the collective), and the
quantisation error is kept locally and *re-injected* into the next step's gradient
(error feedback), which restores convergence to near-fp32 quality.

4× less collective traffic on the dp axis; used inside ``shard_map`` trainers.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.utils.compat import axis_size

F32 = jnp.float32


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    absmax = jnp.max(jnp.abs(x)) + 1e-12
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale


def compressed_psum(grad: jax.Array, axis_name: str, error: jax.Array):
    """One tensor: error-feedback int8 psum.  Returns (mean_grad, new_error).

    All shards first agree on a SHARED scale (one scalar pmax — negligible
    traffic), so the int8 payloads sum exactly in int32; the only loss is local
    quantisation error, which error feedback re-injects next step.
    """
    g = grad.astype(F32) + error
    absmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name) + 1e-12
    scale = absmax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    new_error = g - q.astype(F32) * scale
    tot = jax.lax.psum(q.astype(jnp.int32), axis_name)   # int8 wire, int32 accum
    n = axis_size(axis_name)
    mean = tot.astype(F32) * scale / n
    return mean, new_error


def compressed_grad_sync(grads, axis_name: str, errors):
    """Pytree version.  Returns (synced_grads, new_errors)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [compressed_psum(g, axis_name, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_errors(grads_shape):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads_shape)
