"""Straggler detection + mitigation policy hooks.

At thousand-node scale, slow hosts (thermal throttling, failing HBM, noisy
neighbours) stretch every synchronous step.  The monitor keeps an EWMA/EWVAR of step
times per worker and flags outliers; the policy decides between logging, excluding
the worker from the next elastic re-mesh, or requesting a checkpoint-restart without
it.  On this single-host container the monitor is exercised with synthetic timings
(see tests) — the interface is what a cluster launcher consumes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class StragglerConfig:
    alpha: float = 0.1            # EWMA smoothing
    z_threshold: float = 4.0      # flag if step_time > mean + z*std
    min_samples: int = 16
    consecutive: int = 3          # require N consecutive outliers


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig(),
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.cfg = cfg
        self.mean: Dict[int, float] = {}
        self.var: Dict[int, float] = {}
        self.count: Dict[int, int] = {}
        self.streak: Dict[int, int] = {}
        self.flagged: List[int] = []
        self.on_straggler = on_straggler

    def record(self, worker: int, step_time: float) -> bool:
        """Returns True when this worker is (newly) flagged as a straggler.

        Outlier samples are NOT absorbed into the EWMA — otherwise a degrading
        worker drags its own baseline up and never accumulates a streak.
        """
        c = self.count.get(worker, 0)
        is_outlier = False
        if c >= self.cfg.min_samples:
            std = math.sqrt(max(self.var[worker], 1e-12))
            is_outlier = step_time > (self.mean[worker]
                                      + self.cfg.z_threshold * std)
        if c == 0:
            self.mean[worker] = step_time
            self.var[worker] = 0.0
        elif not is_outlier:
            a = self.cfg.alpha
            d = step_time - self.mean[worker]
            self.mean[worker] += a * d
            self.var[worker] = (1 - a) * (self.var[worker] + a * d * d)
        self.count[worker] = c + 1
        if c + 1 < self.cfg.min_samples:
            return False
        self.streak[worker] = self.streak.get(worker, 0) + 1 if is_outlier else 0
        if (self.streak[worker] >= self.cfg.consecutive
                and worker not in self.flagged):
            self.flagged.append(worker)
            if self.on_straggler:
                self.on_straggler(worker, step_time)
            return True
        return False

    def healthy_workers(self, all_workers: List[int]) -> List[int]:
        return [w for w in all_workers if w not in self.flagged]
