"""Training — loss/optimizer loops exercising the scan operators."""
