"""Trainer: jitted train step with sharded state, grad accumulation, checkpoints,
resume, and straggler monitoring.  Works on 1 CPU device or a production mesh
unchanged (shardings degrade to replication)."""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.model import build_model
from repro.training import optimizer as opt_lib
from repro.training.checkpoint import CheckpointManager
from repro.training.straggler import StragglerMonitor
from repro.utils.sharding import dp_axes, param_shardings, use_mesh

F32 = jnp.float32


class Trainer:
    def __init__(self, cfg, opt_cfg: opt_lib.AdamWConfig, *,
                 mesh: Optional[Mesh] = None, ckpt_dir: Optional[str] = None,
                 grad_accum: int = 1, param_dtype=jnp.float32):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.mesh = mesh
        self.model = build_model(cfg)
        self.grad_accum = grad_accum
        self.param_dtype = param_dtype
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.monitor = StragglerMonitor()
        self._step_fn = None

    # ---- state ----
    def init_state(self, key) -> Dict[str, Any]:
        params = self.model.init(key, dtype=self.param_dtype)
        opt = opt_lib.adamw_init(params)
        state = {"params": params, "opt": opt}
        if self.mesh is not None:
            shards = self.state_shardings(state)
            state = jax.tree.map(jax.device_put, state, shards)
        return state

    def state_shardings(self, state):
        assert self.mesh is not None
        return {"params": param_shardings(self.mesh, state["params"]),
                "opt": {"mu": param_shardings(self.mesh, state["opt"]["mu"]),
                        "nu": param_shardings(self.mesh, state["opt"]["nu"]),
                        "step": NamedSharding(self.mesh, P())}}

    def batch_sharding(self, batch):
        assert self.mesh is not None
        dp = dp_axes(self.mesh)
        def spec(x):
            return NamedSharding(self.mesh, P(*( (dp,) + (None,) * (x.ndim - 1) )))
        return jax.tree.map(spec, batch)

    # ---- step ----
    def _build_step(self):
        model, opt_cfg, accum = self.model, self.opt_cfg, self.grad_accum

        def loss_fn(params, batch):
            loss, metrics = model.loss(params, batch)
            return loss, metrics

        def step(state, batch):
            with use_mesh(self.mesh):
                if accum > 1:
                    def micro(carry, mb):
                        g_acc, l_acc = carry
                        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                            state["params"], mb)
                        return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None
                    mbs = jax.tree.map(
                        lambda x: x.reshape(accum, x.shape[0] // accum,
                                            *x.shape[1:]), batch)
                    zeros = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, F32), state["params"])
                    (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
                    grads = jax.tree.map(lambda g: g / accum, grads)
                    loss = loss / accum
                    metrics = {}
                else:
                    (loss, metrics), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(state["params"], batch)
                new_params, new_opt, om = opt_lib.adamw_update(
                    opt_cfg, grads, state["opt"], state["params"])
                metrics = dict(metrics)
                metrics.update(om)
                metrics["loss"] = loss
                return {"params": new_params, "opt": new_opt}, metrics

        if self.mesh is not None:
            self._step_fn = jax.jit(step, donate_argnums=(0,))
        else:
            self._step_fn = jax.jit(step, donate_argnums=(0,))
        return self._step_fn

    def train_step(self, state, batch):
        if self._step_fn is None:
            self._build_step()
        if self.mesh is not None:
            batch = jax.tree.map(jax.device_put, batch,
                                 self.batch_sharding(batch))
        return self._step_fn(state, batch)

    # ---- loop with resume ----
    def fit(self, source, steps: int, *, key=None, log_every: int = 10,
            ckpt_every: int = 0, state=None, log=print) -> Dict[str, Any]:
        key = key if key is not None else jax.random.PRNGKey(0)
        start_step = 0
        if state is None:
            state = self.init_state(key)
            if self.ckpt is not None and self.ckpt.latest_step() is not None:
                start_step = self.ckpt.latest_step()
                shards = (self.state_shardings(state)
                          if self.mesh is not None else None)
                state = self.ckpt.restore(start_step, state, shardings=shards)
                log(f"[trainer] resumed from step {start_step}")
        losses = []
        for step in range(start_step, steps):
            batch = source.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.monitor.record(0, dt)
            losses.append(loss)
            if log_every and (step + 1) % log_every == 0:
                log(f"[trainer] step {step + 1} loss {loss:.4f} "
                    f"({dt * 1e3:.1f} ms)")
            if self.ckpt is not None and ckpt_every and \
                    (step + 1) % ckpt_every == 0:
                self.ckpt.save(step + 1, state)
        if self.ckpt is not None:
            self.ckpt.wait()
        return {"state": state, "losses": losses}
