"""Utilities — sharding/mesh compat shims."""
