"""Mesh context + path-rule based parameter/activation sharding.

Parameter shardings are derived from tensor-name rules (Megatron-style 2D layout):
vocab/ff/head dims over ``"model"``, batch over ``("pod","data")`` (dp), sequence over
``"data"`` for long-context decode (SP).  All rules degrade to replication when the
named mesh axis does not exist.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def dp_axes(mesh: Mesh):
    """The batch ("data-parallel") mesh axes: ('pod','data') when pods exist."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names) or None


def mdl_axis(mesh: Mesh):
    return "model" if "model" in mesh.axis_names else None


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint if a mesh is active, else identity.

    spec entries: "dp" (batch axes), "model", "data", None.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    resolved = []
    for s in spec:
        if s == "dp":
            resolved.append(dp_axes(mesh))
        elif s in ("model", "data", "pod"):
            resolved.append(s if s in mesh.axis_names else None)
        else:
            resolved.append(s)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# Parameter sharding rules (matched against '/'-joined param paths)
# ---------------------------------------------------------------------------
# (regex, spec builder); specs are for the *unstacked* tensor — a leading layer-stack
# dimension (from scan-over-layers) is detected by rank and padded with None.

_RULES = [
    # embeddings / lm head: (vocab, d) — shard vocab over model
    (re.compile(r"(embed|lm_head|unembed)"), ("model", None)),
    # MoE experts: (E, d, f) / (E, f, d) — expert-parallel over model
    (re.compile(r"experts.*w_(gate|up)$"), ("model", None, None)),
    (re.compile(r"experts.*w_down$"), ("model", None, None)),
    (re.compile(r"router/w$"), (None, None)),
    # attention projections
    (re.compile(r"(wq|wk|wv|wqkv|q_b|kv_b|w_qkv)$"), (None, "model")),
    (re.compile(r"(wo|out_proj)$"), ("model", None)),
    (re.compile(r"(q_a|kv_a)$"), (None, None)),          # MLA low-rank: small, replicate
    # mlp
    (re.compile(r"(w_gate|w_up|w_in|in_proj)$"), (None, "model")),
    (re.compile(r"(w_down|w_out|down_proj)$"), ("model", None)),
    # mamba / xlstm projections
    (re.compile(r"(conv_w|conv_b|a_log|dt_bias|d_skip)$"), None),
    # biases on model-sharded outputs
    (re.compile(r"(wq|wk|wv|w_gate|w_up|w_in)_b$"), ("model",)),
]


def spec_for_path(path: str, ndim: int) -> P:
    for rx, spec in _RULES:
        if rx.search(path):
            if spec is None:
                return P()
            spec = tuple(spec)
            if len(spec) < ndim:                       # layer-stacked: pad left
                spec = (None,) * (ndim - len(spec)) + spec
            elif len(spec) > ndim:
                spec = spec[-ndim:]
            return P(*spec)
    return P()                                          # norms, scalars: replicate


def _path_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params) -> dict:
    """PartitionSpec pytree for a param pytree, by path rules."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, x: spec_for_path(_path_str(kp), jnp.ndim(x)), params)


def param_shardings(mesh: Mesh, params):
    def fix(spec):
        # drop axes that don't exist in this mesh
        cleaned = tuple(a if (a is None or a in mesh.axis_names) else None
                        for a in spec)
        return NamedSharding(mesh, P(*cleaned))
    return jax.tree.map(fix, param_specs(params),
                        is_leaf=lambda x: isinstance(x, P))
