"""Compatibility shims across the jax release range we support.

The repo targets current jax, but CI (and minimal environments) may run the
0.4.x series, where ``jax.sharding.AxisType`` / ``Mesh(axis_types=...)`` and
the top-level ``jax.shard_map`` don't exist yet.  Everything that needs one of
those goes through here.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit/auto axis types on meshes
    from jax.sharding import AxisType  # noqa: F401
    _HAVE_AXIS_TYPES = True
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None
    _HAVE_AXIS_TYPES = False

try:  # jax >= 0.5: shard_map graduated to the top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map  # noqa: F401


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with per-op replication checking off.

    ``pallas_call`` has no replication rule (any jax we support), so bodies
    that launch Pallas kernels — e.g. ``mcscan``'s fused blocked pipeline or
    the Pallas-method distributed operators in ``repro.core.dist_ops`` —
    must disable the check.  The kwarg was renamed ``check_rep`` ->
    ``check_vma`` across jax releases; try both.

    Warn path: with checking off, jax no longer *verifies* that values under
    replicated ``out_specs`` are actually identical across shards — on newer
    jax the first call may emit a ``UserWarning`` about unchecked replication
    instead of a hard error.  That trade is deliberate and safe here: every
    unchecked body in this repo only ever returns (a) per-shard outputs under
    sharded specs or (b) values produced by ``psum``/``all_gather``, which
    are replicated by construction; the multi-device parity suites
    (``tests/test_distributed.py``, ``tests/test_dist_ops.py``) verify the
    gathered results against the single-device siblings, which would catch
    any divergence such a check would have.
    """
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_rep=False)
    except TypeError:  # pragma: no cover - depends on installed jax
        return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                         check_vma=False)


def axis_size(axis_name):
    """Static size of a named mesh axis, inside ``shard_map``."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    import jax.core as _core  # 0.4.x: axis_frame(name) returns the int size
    return _core.axis_frame(axis_name)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the installed jax has them."""
    if _HAVE_AXIS_TYPES:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
