"""Mamba2 block (used by zamba2): selective SSM whose sequence mixing runs through
the chunked matmul scan (``repro.core.ssd`` / the Pallas ``ssd_chunk`` kernel) — the
paper's scan-via-MXU idea as a model layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linrec import linear_scan
from repro.core.ssd import ssd_scan
from repro.kernels.ops import ssd_kernel
from repro.models.layers import linear, ninit, rmsnorm, rmsnorm_init

F32 = jnp.float32


def mamba_init(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_inner = s.expand * d
    g = s.n_groups
    conv_dim = d_inner + 2 * g * s.d_state
    ks = jax.random.split(key, 8)
    return {
        # order: [z (d_inner), x (d_inner), B (g*N), C (g*N), dt (H)]
        "in_proj": ninit(ks[0], (d, 2 * d_inner + 2 * g * s.d_state + s.n_heads),
                         dtype=dtype),
        "conv_w": ninit(ks[1], (s.conv_kernel, conv_dim), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, s.n_heads)).astype(dtype),
        "dt_bias": jnp.zeros((s.n_heads,), dtype),
        "d_skip": jnp.ones((s.n_heads,), dtype),
        "gate_norm": rmsnorm_init(d_inner, dtype),
        "out_proj": ninit(ks[2], (d_inner, d), dtype=dtype),
    }


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv. x: (B,S,C); w: (K,C). Returns (y, new_cache)."""
    k = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + b
    new_cache = xp[:, -(k - 1):, :]
    return y, new_cache


def _project(p, x, cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    g = s.n_groups
    zxbcdt = linear({"w": p["in_proj"]}, x)
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + g * s.d_state,
         2 * d_inner + 2 * g * s.d_state], axis=-1)
    return z, xin, bmat, cmat, dt


def _gates(p, dt):
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))   # (B,S,H)
    a_log = -jnp.exp(p["a_log"].astype(F32)) * dt                      # log decay
    return dt, a_log


def mamba_full(p, x, cfg, *, return_cache=False, use_kernel=False):
    """Full-sequence Mamba2 mixer. x: (B,S,D)."""
    s = cfg.ssm
    b, seq, _ = x.shape
    d_inner = s.expand * cfg.d_model
    g = s.n_groups
    z, xin, bmat, cmat, dt = _project(p, x, cfg)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, conv_cache = _causal_conv(conv_in, p["conv_w"].astype(x.dtype),
                                        p["conv_b"].astype(x.dtype))
    conv_out = jax.nn.silu(conv_out)
    xin, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + g * s.d_state], axis=-1)
    dt, a_log = _gates(p, dt)
    xh = xin.reshape(b, seq, s.n_heads, s.head_dim)
    xh = xh * dt[..., None]                            # fold dt into inputs
    rep = s.n_heads // g
    bm = jnp.repeat(bmat.reshape(b, seq, g, s.d_state), rep, axis=2)
    cm = jnp.repeat(cmat.reshape(b, seq, g, s.d_state), rep, axis=2)
    if use_kernel and cfg.scan_method == "kernel":
        y = ssd_kernel(xh.astype(F32), a_log, bm.astype(F32), cm.astype(F32),
                       chunk=s.chunk)
        state = None
    else:
        y, state = ssd_scan(xh.astype(F32), a_log, bm.astype(F32), cm.astype(F32),
                            chunk=s.chunk, scan_method=cfg.scan_method,
                            return_final_state=True)
    y = y + xh * p["d_skip"].astype(F32)[:, None]
    y = y.reshape(b, seq, d_inner).astype(x.dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear({"w": p["out_proj"]}, y)
    if return_cache:
        return out, {"conv": conv_cache, "ssm": state.astype(F32)}
    return out


def mamba_step(p, x, cfg, cache):
    """Single-token decode step. x: (B,1,D); cache: {conv (B,K-1,C), ssm (B,H,N,P)}.

    The state update ``h = exp(a)·h + B ⊗ x`` is a length-1 linear recurrence,
    routed through :func:`repro.core.linrec.linear_scan` under
    ``cfg.scan_method`` — the same dispatch surface as prefill (length-1
    scans short-circuit to the direct fused multiply-add, bit-identical for
    every method, so decode pays no per-token kernel launch).
    """
    s = cfg.ssm
    b = x.shape[0]
    d_inner = s.expand * cfg.d_model
    g = s.n_groups
    z, xin, bmat, cmat, dt = _project(p, x, cfg)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, conv_cache = _causal_conv(conv_in, p["conv_w"].astype(x.dtype),
                                        p["conv_b"].astype(x.dtype),
                                        cache=cache["conv"])
    conv_out = jax.nn.silu(conv_out)
    xin, bmat, cmat = jnp.split(conv_out, [d_inner, d_inner + g * s.d_state], axis=-1)
    dt, a_log = _gates(p, dt)                          # (B,1,H)
    xh = (xin.reshape(b, 1, s.n_heads, s.head_dim) * dt[..., None])[:, 0]  # (B,H,P)
    rep = s.n_heads // g
    bm = jnp.repeat(bmat.reshape(b, g, s.d_state), rep, axis=1)            # (B,H,N)
    cm = jnp.repeat(cmat.reshape(b, g, s.d_state), rep, axis=1)
    h = cache["ssm"]                                   # (B,H,N,P) f32
    decay = jnp.exp(a_log[:, 0])[..., None, None]      # (B,H,1,1)
    upd = jnp.einsum("bhn,bhp->bhnp", bm.astype(F32), xh.astype(F32))
    h = linear_scan(decay[..., None], upd[..., None], axis=-1,
                    method=cfg.scan_method, initial=h)[..., 0]
    y = jnp.einsum("bhn,bhnp->bhp", cm.astype(F32), h)
    y = y + xh.astype(F32) * p["d_skip"].astype(F32)[:, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = linear({"w": p["out_proj"]}, y)
    return out, {"conv": conv_cache, "ssm": h}
