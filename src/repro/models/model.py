"""Model registry + input specs for every (architecture × shape) cell."""
from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import TransformerLM

ARCHS = {
    "whisper-small": "repro.configs.whisper_small",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "llama3-8b": "repro.configs.llama3_8b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "llama4-scout-17b-16e": "repro.configs.llama4_scout_17b_16e",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "xlstm-350m": "repro.configs.xlstm_350m",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def build_model(cfg: ModelConfig) -> TransformerLM:
    return TransformerLM(cfg)


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether this (arch × shape) cell runs; reason when skipped (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.supports_long:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cdt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    s_text = s - (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    specs = {"tokens": jax.ShapeDtypeStruct((b, s_text), i32)}
    if cfg.family == "vlm":
        specs["img_embed"] = jax.ShapeDtypeStruct((b, cfg.n_img_tokens,
                                                   cfg.d_model), cdt)
    if cfg.family == "encdec":
        specs["enc_embed"] = jax.ShapeDtypeStruct((b, cfg.enc_len,
                                                   cfg.d_model), cdt)
    return specs


def synth_batch(cfg: ModelConfig, shape: ShapeConfig, key) -> Dict:
    """Random concrete batch matching input_specs (smoke tests / examples)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, sds in specs.items():
        key, k = jax.random.split(key)
        if sds.dtype == jnp.int32:
            out[name] = jax.random.randint(k, sds.shape, 0,
                                           min(cfg.vocab_size, 1000), jnp.int32)
        else:
            out[name] = (jax.random.normal(k, sds.shape) * 0.3).astype(sds.dtype)
    return out
