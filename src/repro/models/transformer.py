"""Model assembly for all assigned architecture families.

Layers are stacked with ``lax.scan`` over grouped parameter pytrees (bounded HLO size
— essential for compiling 62-layer models against a 512-device mesh).  Heterogeneous
layer patterns (gemma2 local/global, xlstm 3×mLSTM+sLSTM, zamba2 6×mamba+shared-attn)
scan over *pattern groups*.

Interface (per built model):
  init(key) -> params
  forward(params, batch)                        -> logits               (train)
  prefill(params, batch, cache_len)             -> (logits, caches)
  decode_step(params, tokens, caches, pos)      -> (logits, caches)
  loss(params, batch)                           -> (scalar, metrics)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention as att
from repro.models import mamba as mmb
from repro.models import moe as moe_lib
from repro.models import xlstm as xl
from repro.models.layers import (embed_init, embed_lookup, mlp,
                                 mlp_init, rmsnorm, rmsnorm_init,
                                 sinusoidal_pos, softcap, unembed,
                                 use_compute_dtype)
from repro.utils.sharding import constrain

F32 = jnp.float32


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _block_init(key, cfg, kind: str, dtype=jnp.float32):
    """One residual block. kind: dense|local|mla|moe|mamba|mlstm|slstm|enc|dec."""
    ks = jax.random.split(key, 4)
    p = {}
    if kind == "mamba":
        p["norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["mixer"] = mmb.mamba_init(ks[0], cfg, dtype)
        return p
    if kind == "mlstm":
        p["norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["mixer"] = xl.mlstm_block_init(ks[0], cfg, dtype)
        return p
    if kind == "slstm":
        p["norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["mixer"] = xl.slstm_block_init(ks[0], cfg, dtype)
        return p
    p["norm1"] = rmsnorm_init(cfg.d_model, dtype)
    p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
    if kind == "mla":
        p["attn"] = att.mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = att.attn_init(ks[0], cfg, dtype=dtype)
    if kind == "dec":                               # whisper decoder: + cross attn
        p["norm_x"] = rmsnorm_init(cfg.d_model, dtype)
        p["xattn"] = att.attn_init(ks[2], cfg, cross=True, dtype=dtype)
    if kind == "moe":
        p["moe"] = moe_lib.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                            gated=cfg.act != "gelu_nogate", dtype=dtype)
    if cfg.name.startswith("gemma2"):               # sandwich norms
        p["post_norm1"] = rmsnorm_init(cfg.d_model, dtype)
        p["post_norm2"] = rmsnorm_init(cfg.d_model, dtype)
    return p


def _maybe_post(p, name, x, cfg):
    return rmsnorm(p[name], x, cfg.norm_eps) if name in p else x


def _block_apply(p, h, cfg, kind: str, *, positions=None, mode="train",
                 cache=None, pos=None, prefix_len=None, enc_out=None,
                 cache_len=None, causal=True):
    """Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), F32)
    window = cfg.local_window if kind == "local" else None
    new_cache = None

    if kind in ("mamba", "mlstm", "slstm"):
        hin = rmsnorm(p["norm"], h, cfg.norm_eps)
        if kind == "mamba":
            if mode == "decode":
                y, new_cache = mmb.mamba_step(p["mixer"], hin, cfg, cache)
            elif mode == "prefill":
                y, new_cache = mmb.mamba_full(p["mixer"], hin, cfg, return_cache=True)
            else:
                y = mmb.mamba_full(p["mixer"], hin, cfg,
                                   use_kernel=cfg.scan_method == "kernel")
        elif kind == "mlstm":
            if mode == "decode":
                y, new_cache = xl.mlstm_block_step(p["mixer"], hin, cfg, cache)
            elif mode == "prefill":
                y, new_cache = xl.mlstm_block(p["mixer"], hin, cfg, return_cache=True)
            else:
                y = xl.mlstm_block(p["mixer"], hin, cfg)
        else:
            if mode == "decode":
                y, new_cache = xl.slstm_block_step(p["mixer"], hin, cfg, cache)
            elif mode == "prefill":
                y, new_cache = xl.slstm_block(p["mixer"], hin, cfg, return_cache=True)
            else:
                y = xl.slstm_block(p["mixer"], hin, cfg)
        return h + y, new_cache, aux

    # ---- attention sub-block ----
    hin = rmsnorm(p["norm1"], h, cfg.norm_eps)
    full_cache = cache
    if kind == "dec" and cache is not None:
        cache = cache["kv"]          # self-attn part of the enc-dec cache
    if kind == "mla":
        if mode == "decode":
            y, new_cache = att.mla_decode(p["attn"], hin, cfg, cache, pos)
        elif mode == "prefill":
            y, new_cache = att.mla_full(p["attn"], hin, cfg, positions=positions,
                                        return_cache=True, cache_len=cache_len)
        else:
            y = att.mla_full(p["attn"], hin, cfg, positions=positions)
    else:
        if mode == "decode":
            # a "pages" leaf marks the paged KV layout (continuous batching);
            # plain {"k","v"} caches stay on the dense kv_layout baseline
            dec = (att.attn_decode_paged if (cache is not None
                                             and "pages" in cache)
                   else att.attn_decode)
            y, new_cache = dec(p["attn"], hin, cfg, cache, pos,
                               window=window)
        elif mode == "prefill":
            y, new_cache = att.attn_full(p["attn"], hin, cfg, positions=positions,
                                         causal=causal, window=window,
                                         prefix_len=prefix_len, return_cache=True,
                                         cache_len=cache_len)
        else:
            y = att.attn_full(p["attn"], hin, cfg, positions=positions,
                              causal=causal, window=window, prefix_len=prefix_len)
    y = _maybe_post(p, "post_norm1", y, cfg)
    h = h + y

    # ---- cross attention (whisper decoder) ----
    if kind == "dec":
        hin = rmsnorm(p["norm_x"], h, cfg.norm_eps)
        if mode == "decode":
            y = att.attn_cross_decode(p["xattn"], hin, cfg, full_cache["xkv"])
        else:
            y = att.attn_full(p["xattn"], hin, cfg, positions=None, kv_x=enc_out,
                              use_rope=False)
            if mode == "prefill":
                new_cache = {"kv": new_cache,
                             "xkv": att.cross_kv(p["xattn"], enc_out, cfg)}
        h = h + y
        if mode == "decode":
            new_cache = {"kv": new_cache, "xkv": full_cache["xkv"]}
    elif mode in ("prefill", "decode") and new_cache is not None:
        pass

    # ---- mlp / moe sub-block ----
    hin = rmsnorm(p["norm2"], h, cfg.norm_eps)
    if kind == "moe":
        y, aux = moe_lib.moe_apply(p["moe"], hin, cfg, no_drop=mode == "decode")
    else:
        y = mlp(p["mlp"], hin, act=cfg.act)
    y = _maybe_post(p, "post_norm2", y, cfg)
    return h + y, new_cache, aux


def _decode_cache_for(kind, cfg, h, cache_len, block_params=None, enc_out=None):
    """Empty caches for pure-decode dry-runs (shape/dtype only)."""
    b = h.shape[0]
    dt = h.dtype
    hd = cfg.head_dim_
    if kind in ("dense", "local", "global", "moe", "enc", "mla_naive"):
        return {"k": jnp.zeros((b, cache_len, cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((b, cache_len, cfg.n_kv_heads, hd), dt)}
    if kind == "dec":
        return {"kv": {"k": jnp.zeros((b, cache_len, cfg.n_kv_heads, hd), dt),
                       "v": jnp.zeros((b, cache_len, cfg.n_kv_heads, hd), dt)},
                "xkv": {"k": jnp.zeros((b, cfg.enc_len, cfg.n_kv_heads, hd), dt),
                        "v": jnp.zeros((b, cfg.enc_len, cfg.n_kv_heads, hd), dt)}}
    if kind == "mla":
        m = cfg.mla
        return {"latent": jnp.zeros((b, cache_len, m.kv_lora_rank), dt),
                "k_rope": jnp.zeros((b, cache_len, m.qk_rope_head_dim), dt)}
    if kind == "mamba":
        s = cfg.ssm
        conv_dim = s.expand * cfg.d_model + 2 * s.n_groups * s.d_state
        return {"conv": jnp.zeros((b, s.conv_kernel - 1, conv_dim), dt),
                "ssm": jnp.zeros((b, s.n_heads, s.d_state, s.head_dim), F32)}
    if kind == "mlstm":
        x = cfg.xlstm
        d_inner = int(x.proj_factor * cfg.d_model)
        hdx = d_inner // x.n_heads
        return {"conv": jnp.zeros((b, x.conv_kernel - 1, d_inner), dt),
                "c": jnp.zeros((b, x.n_heads, hdx, hdx), F32),
                "n": jnp.zeros((b, x.n_heads, hdx), F32),
                "m": jnp.full((b, x.n_heads), -1e30, F32)}
    if kind == "slstm":
        x = cfg.xlstm
        hdx = cfg.d_model // x.n_heads
        z = jnp.zeros((b, x.n_heads, hdx), F32)
        return {"conv": jnp.zeros((b, x.conv_kernel - 1, cfg.d_model), dt),
                "rec": (z, z, jnp.full((b, x.n_heads, hdx), -1e30, F32), z)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# the LM
# ---------------------------------------------------------------------------


class TransformerLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.pattern = self._pattern()
        self.group = len(self.pattern)
        assert cfg.n_layers % self.group == 0 or cfg.family == "hybrid", \
            (cfg.name, cfg.n_layers, self.pattern)

    # ---- architecture pattern ----
    def _pattern(self):
        cfg = self.cfg
        if cfg.layer_pattern:
            return tuple(cfg.layer_pattern)
        if cfg.family == "xlstm":
            k = cfg.xlstm.slstm_every
            return tuple(["mlstm"] * (k - 1) + ["slstm"])
        if cfg.family == "moe":
            return ("moe",)
        if cfg.family == "encdec":
            return ("dec",)
        if cfg.mla is not None:
            return ("mla",)
        return ("dense",)

    # ---- init ----
    def init(self, key, dtype=None):
        cfg = self.cfg
        dtype = dtype or jnp.float32
        keys = jax.random.split(key, 8)
        p = {"embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype,
                                 scale=cfg.d_model ** -0.5),
             "final_norm": rmsnorm_init(cfg.d_model, dtype)}

        def stack_init(key, n, kinds):
            def one(k):
                ks = jax.random.split(k, len(kinds))
                return {f"sub{i}": _block_init(ks[i], cfg, kind, dtype)
                        for i, kind in enumerate(kinds)}
            return jax.vmap(one)(jax.random.split(key, n))

        if cfg.family == "hybrid":
            iv = cfg.shared_attn_interval
            n_groups = cfg.n_layers // iv
            trailing = cfg.n_layers - n_groups * iv
            p["stack"] = stack_init(keys[1], n_groups, ("mamba",) * iv)
            p["shared"] = _block_init(keys[2], cfg, "dense", dtype)
            if trailing:
                p["tail"] = stack_init(keys[3], trailing, ("mamba",))
        elif cfg.family == "encdec":
            p["enc_stack"] = stack_init(keys[1], cfg.n_enc_layers, ("enc",))
            p["stack"] = stack_init(keys[2], cfg.n_layers, ("dec",))
            p["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
        else:
            moe = self.cfg.moe
            pre = moe.first_k_dense if moe else 0
            if pre:
                p["pre"] = stack_init(keys[3], pre, ("dense",))
            p["stack"] = stack_init(
                keys[1], (cfg.n_layers - pre) // self.group, self.pattern)
        return p

    # ---- layer-stack scan helper ----
    def _scan_stack(self, params, h, kinds, *, mode, positions=None, caches=None,
                    pos=None, prefix_len=None, enc_out=None, cache_len=None,
                    causal=True):
        cfg = self.cfg

        def group_body(h, p_group, cache_group):
            new_caches = []
            aux = jnp.zeros((), F32)
            for i, kind in enumerate(kinds):
                c_i = None if cache_group is None else cache_group[f"sub{i}"]
                h, nc, a = _block_apply(
                    p_group[f"sub{i}"], h, cfg, kind, positions=positions,
                    mode=mode, cache=c_i, pos=pos, prefix_len=prefix_len,
                    enc_out=enc_out, cache_len=cache_len, causal=causal)
                aux = aux + a
                new_caches.append(nc)
            out_cache = ({f"sub{i}": c for i, c in enumerate(new_caches)}
                         if new_caches[0] is not None else None)
            return h, out_cache, aux

        if not cfg.scan_layers:
            # Unrolled layers: bigger HLO, but cost_analysis counts every layer
            # (XLA counts while-loop bodies ONCE — see DESIGN.md §6) — used by the
            # dry-run so the roofline terms are exact.
            gb = (jax.checkpoint(group_body) if (cfg.remat and mode == "train")
                  else group_body)
            n = jax.tree.leaves(params)[0].shape[0]
            auxs, ncs = jnp.zeros((), F32), []
            for i in range(n):
                p_g = jax.tree.map(lambda a: a[i], params)
                c_g = None if caches is None else jax.tree.map(
                    lambda a: a[i], caches)
                h, nc, aux = gb(h, p_g, c_g)
                auxs = auxs + aux
                ncs.append(nc)
            new_caches = (None if ncs[0] is None
                          else jax.tree.map(lambda *a: jnp.stack(a), *ncs))
            return h, new_caches, auxs

        def f(carry, xs):
            h = carry
            p_g, c_g = (xs, None) if caches is None else xs
            h, nc, aux = group_body(h, p_g, c_g)
            return h, (nc, aux)      # nc=None is an empty pytree — fine for scan ys

        body = jax.checkpoint(f) if (cfg.remat and mode == "train") else f
        xs = params if caches is None else (params, caches)
        h, (new_caches, aux) = jax.lax.scan(body, h, xs)
        return h, new_caches, jnp.sum(aux)

    # ---- embedding helpers ----
    def _embed(self, params, tokens):
        cfg = self.cfg
        h = embed_lookup(params["embed"], tokens)
        if cfg.scale_embed:
            h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
        return h

    def _logits(self, params, h):
        cfg = self.cfg
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = unembed(params["embed"], h)
        logits = softcap(logits, cfg.final_softcap)
        if cfg.padded_vocab != cfg.vocab_size:      # mask padded vocab rows
            iota = jnp.arange(cfg.padded_vocab, dtype=jnp.int32)
            logits = jnp.where(iota < cfg.vocab_size, logits, -1e30)
        return logits

    def _encode(self, params, enc_embed):
        """Whisper encoder over (stub) frame embeddings."""
        cfg = self.cfg
        h = enc_embed.astype(jnp.dtype(cfg.dtype))
        h = h + sinusoidal_pos(h.shape[1], cfg.d_model, h.dtype)[None]
        h, _, _ = self._scan_stack(params["enc_stack"], h, ("enc",),
                                   mode="train", positions=None, causal=False)
        return rmsnorm(params["enc_norm"], h, cfg.norm_eps)

    # ---- forward paths ----
    def _run(self, params, batch, *, mode, cache_len=None, caches=None, pos=None):
        with use_compute_dtype(jnp.dtype(self.cfg.dtype)):
            return self._run_inner(params, batch, mode=mode, cache_len=cache_len,
                                   caches=caches, pos=pos)

    def _run_inner(self, params, batch, *, mode, cache_len=None, caches=None,
                   pos=None):
        cfg = self.cfg
        tokens = batch["tokens"]
        h = self._embed(params, tokens)
        h = constrain(h, "dp", None, None)
        prefix_len = None
        enc_out = None

        if cfg.family == "vlm" and mode != "decode":
            img = batch["img_embed"].astype(h.dtype)
            h = jnp.concatenate([img, h], axis=1)
            prefix_len = cfg.n_img_tokens
        if cfg.family == "encdec" and mode != "decode":
            enc_out = self._encode(params, batch["enc_embed"])
        if cfg.family == "encdec":
            # whisper-style absolute decoder positions (sinusoidal stand-in)
            if mode == "decode":
                d = cfg.d_model
                inv = jnp.exp(jnp.arange(0, d, 2, dtype=F32)
                              * (-jnp.log(10000.0) / d))
                ang = pos.astype(F32) * inv
                pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])
                pe = pe.reshape(2, -1).T.reshape(-1)          # interleave sin/cos
                h = h + pe.astype(h.dtype)[None, None, :]
            else:
                h = h + sinusoidal_pos(h.shape[1], cfg.d_model, h.dtype)[None]

        s = h.shape[1]
        if mode == "decode":
            positions = None
        else:
            positions = jnp.arange(s, dtype=jnp.int32)[None, :]

        aux_total = jnp.zeros((), F32)
        new_caches = {}

        if cfg.family == "hybrid":
            iv = cfg.shared_attn_interval
            # shared attention block applied after each group of `iv` mamba layers
            def with_shared(h, stack_caches, shared_caches):
                def group_body(h, xs):
                    if stack_caches is None:
                        p_g, sc, shc = xs, None, None
                    else:
                        p_g, (sc, shc) = xs
                    new_g = []
                    for i in range(iv):
                        c_i = None if sc is None else jax.tree.map(
                            lambda a: a[i], sc)
                        h, nc, _ = _block_apply(
                            p_g[f"sub{i}"], h, cfg, "mamba", positions=positions,
                            mode=mode, cache=c_i, pos=pos, cache_len=cache_len)
                        new_g.append(nc)
                    # shared block (weights shared across invocations)
                    h, nc_sh, _ = _block_apply(
                        params["shared"], h, cfg, "dense", positions=positions,
                        mode=mode, cache=shc, pos=pos, cache_len=cache_len)
                    ys = None
                    if new_g[0] is not None:
                        stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_g)
                        ys = (stacked, nc_sh)
                    return h, ys
                if not cfg.scan_layers:
                    gb = (jax.checkpoint(group_body)
                          if (cfg.remat and mode == "train") else group_body)
                    yss = []
                    n = jax.tree.leaves(params["stack"])[0].shape[0]
                    for gi in range(n):
                        p_g = jax.tree.map(lambda a: a[gi], params["stack"])
                        if stack_caches is None:
                            xs_i = p_g
                        else:
                            xs_i = (p_g, (jax.tree.map(lambda a: a[gi],
                                                       stack_caches),
                                          jax.tree.map(lambda a: a[gi],
                                                       shared_caches)))
                        h, ys_i = gb(h, xs_i)
                        yss.append(ys_i)
                    ys = (None if yss[0] is None
                          else jax.tree.map(lambda *a: jnp.stack(a), *yss))
                    return h, ys
                xs = (params["stack"] if stack_caches is None
                      else (params["stack"], (stack_caches, shared_caches)))
                h, ys = jax.lax.scan(group_body, h, xs)
                return h, ys
            stack_c = None if caches is None else caches["stack"]
            shared_c = None if caches is None else caches["shared"]
            h, ys = with_shared(h, stack_c, shared_c)
            if ys is not None:
                new_caches["stack"], new_caches["shared"] = ys
            if "tail" in params:
                tc = None if caches is None else caches["tail"]
                h, ntc, _ = self._scan_stack(
                    params["tail"], h, ("mamba",), mode=mode, positions=positions,
                    caches=tc, pos=pos, cache_len=cache_len)
                if ntc is not None:
                    new_caches["tail"] = ntc
        else:
            if "pre" in params:
                pc = None if caches is None else caches["pre"]
                h, npc, _ = self._scan_stack(
                    params["pre"], h, ("dense",), mode=mode, positions=positions,
                    caches=pc, pos=pos, cache_len=cache_len)
                if npc is not None:
                    new_caches["pre"] = npc
            sc = None if caches is None else caches["stack"]
            h, nsc, aux = self._scan_stack(
                params["stack"], h, self.pattern, mode=mode, positions=positions,
                caches=sc, pos=pos, prefix_len=prefix_len, enc_out=enc_out,
                cache_len=cache_len)
            aux_total = aux_total + aux
            if nsc is not None:
                new_caches["stack"] = nsc

        logits = self._logits(params, h)
        if mode == "train":
            return logits, aux_total
        return logits, new_caches

    # ---- public API ----
    def forward(self, params, batch):
        logits, _ = self._run(params, batch, mode="train")
        return logits

    def loss(self, params, batch):
        cfg = self.cfg
        logits, aux = self._run(params, batch, mode="train")
        tokens = batch["tokens"]
        if cfg.family == "vlm":        # predictions for text positions only
            logits = logits[:, cfg.n_img_tokens:]
        targets = tokens[:, 1:]
        lg = logits[:, :-1].astype(F32)
        # vocab-parallel-friendly CE: logsumexp + masked correct-logit sum — no
        # cross-shard gather when the vocab axis is model-sharded.
        logz = jax.nn.logsumexp(lg, axis=-1)
        iota = jnp.arange(lg.shape[-1], dtype=jnp.int32)
        correct = jnp.sum(jnp.where(iota[None, None, :] == targets[..., None],
                                    lg, 0.0), axis=-1)
        nll = logz - correct
        mask = batch.get("loss_mask")
        if mask is not None:
            mask = mask[:, 1:].astype(F32)
            ce = jnp.sum(nll * mask) / jnp.clip(jnp.sum(mask), 1.0)
        else:
            ce = jnp.mean(nll)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    def prefill(self, params, batch, *, cache_len=None):
        logits, caches = self._run(params, batch, mode="prefill",
                                   cache_len=cache_len)
        return logits[:, -1], caches

    def decode_step(self, params, tokens, caches, pos):
        """tokens: (B,1) int32; pos: scalar int32 write position, or per-row
        (B,) int32 for attention-only models (continuous batching)."""
        logits, caches = self._run(params, {"tokens": tokens}, mode="decode",
                                   caches=caches, pos=pos)
        return logits[:, -1], caches

    # ---- decode-cache specs for dry-runs ----
    def empty_caches(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        h = jnp.zeros((batch_size, 1, cfg.d_model), dt)

        def stack_cache(n, kinds):
            def one(_):
                return {f"sub{i}": _decode_cache_for(k, cfg, h, cache_len)
                        for i, k in enumerate(kinds)}
            return jax.vmap(one)(jnp.arange(n))

        c = {}
        if cfg.family == "hybrid":
            iv = cfg.shared_attn_interval
            n_groups = cfg.n_layers // iv
            trailing = cfg.n_layers - n_groups * iv

            def one_group(_):
                sc = jax.vmap(lambda _: _decode_cache_for("mamba", cfg, h,
                                                          cache_len))(jnp.arange(iv))
                return (sc, _decode_cache_for("dense", cfg, h, cache_len))
            grouped = jax.vmap(one_group)(jnp.arange(n_groups))
            c["stack"], c["shared"] = grouped
            if trailing:
                c["tail"] = stack_cache(trailing, ("mamba",))
            return c
        moe = cfg.moe
        pre = moe.first_k_dense if moe else 0
        if pre:
            c["pre"] = stack_cache(pre, ("dense",))
        kinds = self.pattern
        c["stack"] = stack_cache((cfg.n_layers - pre) // self.group, kinds)
        return c
