"""Attention variants: GQA/MQA (+qk-norm, softcap, local windows, prefix-LM),
cross-attention (whisper), and MLA (minicpm3) with an absorbed decode path.

All full-sequence paths take (B,S,D) and return (B,S,D); decode paths take a KV cache
pytree plus the write position and update it functionally.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core import guards
from repro.models.layers import (apply_rope, linear, ninit,
                                 rmsnorm, rmsnorm_init, softcap)
from repro.utils.sharding import constrain

F32 = jnp.float32


def _cache_len(cache_len, s: int, *, op: str) -> int:
    """Resolve the KV-cache length for a prefill of ``s`` tokens.

    ``cache_len=None`` means "size the cache to the prompt"; any explicit
    value must be a positive int >= ``s`` (``cache_len=0`` used to fall
    through a falsy-``or`` onto ``s`` silently, and a cache shorter than the
    prompt would silently clip the out-of-bounds scatter).
    """
    if cache_len is None:
        return s
    clen = guards.validate_positive(cache_len, name="cache_len", op=op)
    if clen < s:
        raise ValueError(f"{op}: cache_len ({clen}) is shorter than the "
                         f"prefill length ({s}); the KV cache must hold at "
                         "least the prompt")
    return clen


# ---------------------------------------------------------------------------
# standard / grouped-query attention
# ---------------------------------------------------------------------------


def attn_init(key, cfg, *, cross=False, dtype=jnp.float32):
    hd = cfg.head_dim_
    ks = jax.random.split(key, 6)
    p = {
        "wq": ninit(ks[0], (cfg.d_model, cfg.n_heads * hd), dtype=dtype),
        "wk": ninit(ks[1], (cfg.d_model, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": ninit(ks[2], (cfg.d_model, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": ninit(ks[3], (cfg.n_heads * hd, cfg.d_model), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def _gqa_scores(q, k, scale, cap):
    """q: (B,S,K,G,D), k: (B,T,K,D) -> (B,K,G,S,T) fp32.

    bf16 operands + f32 accumulation (preferred_element_type): any all-gather of
    q/k that SPMD inserts moves bf16, not f32 (§Perf I4)."""
    s = jnp.einsum("bskgd,btkd->bkgst", q, k,
                   preferred_element_type=F32) * scale
    return softcap(s, cap)


def _gqa_out(probs, v, seq_sharded=False):
    """probs: (B,K,G,S,T), v: (B,T,K,D) -> (B,S,K*G,D).

    probs are cast to v's dtype (bf16 in production — flash-attention-standard)
    so v's all-gather and the dot stay in bf16 with f32 accumulation."""
    o = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                   preferred_element_type=F32)
    if seq_sharded:
        # pin the dot output to the query-sequence sharding so GSPMD never
        # reshards the f32 probs inside the einsum (involuntary full remat)
        o = constrain(o, "dp", "model", None, None, None)
    b, s, k, g, d = o.shape
    return o.reshape(b, s, k * g, d)


def _tp_size():
    from repro.utils.sharding import current_mesh
    mesh = current_mesh()
    return mesh.shape.get("model", 1) if mesh is not None else 1


def _attn_head_spec(cfg):
    """Head-axis sharding for attention intermediates.

    When the TP degree does not divide n_kv_heads, GSPMD's fallback is
    catastrophic: it shards the q·k CONTRACTION dim and all-reduces the full
    S×T score matrix (observed: 223 GB/chip of f32[32768,32768] ARs on
    gemma2 prefill).  In that case we pin attention to batch-only sharding —
    the qkv activations get all-gathered once (MBs, not GBs) and attention
    runs locally.  See EXPERIMENTS.md §Perf I1.
    """
    from repro.utils.sharding import current_mesh
    mesh = current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    return "model" if (tp > 1 and cfg.n_kv_heads % tp == 0) else None


def _qk(p, x, cfg, positions, kv_x=None, use_rope=True):
    hd = cfg.head_dim_
    q = _split_heads(linear({"w": p["wq"]}, x), cfg.n_heads, hd)
    src = x if kv_x is None else kv_x
    k = _split_heads(linear({"w": p["wk"]}, src), cfg.n_kv_heads, hd)
    v = _split_heads(linear({"w": p["wv"]}, src), cfg.n_kv_heads, hd)
    hs = _attn_head_spec(cfg)
    q = constrain(q, "dp", None, hs, None)
    k = constrain(k, "dp", None, hs, None)
    v = constrain(v, "dp", None, hs, None)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.rope and use_rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_full(p, x, cfg, *, positions=None, causal=True, window=None,
              prefix_len=None, kv_x=None, use_rope=True, return_cache=False,
              cache_len=None):
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    kh, gh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qk(p, x, cfg, positions, kv_x=kv_x, use_rope=use_rope)
    if _attn_head_spec(cfg) is None and s > 1:
        # context parallelism: kv-heads don't divide TP, so shard the QUERY
        # sequence over "model" instead — attention flops/score memory split
        # TP-ways, softmax (over t) stays local, and no contraction-dim AR
        # (EXPERIMENTS.md §Perf I3).
        q = constrain(q, "dp", "model", None, None)
    qg = q.reshape(b, s, kh, gh, hd)
    scores = _gqa_scores(qg, k, hd ** -0.5, cfg.attn_softcap)
    t = k.shape[1]
    if causal and kv_x is None:
        i = jnp.arange(s)[:, None]
        j = jnp.arange(t)[None, :]
        mask = j <= i
        if window is not None:
            mask &= (i - j) < window
        if prefix_len:
            mask |= (i < prefix_len) & (j < prefix_len)
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    hs = _attn_head_spec(cfg)
    seq_sharded = hs is None and s > 1
    out = _gqa_out(probs, v, seq_sharded=seq_sharded).astype(x.dtype)
    if seq_sharded:
        # reshard the *small bf16* tensor to feature sharding for row-parallel wo
        out = constrain(out.reshape(b, s, -1), "dp", None,
                        "model" if (cfg.n_heads * hd) %
                        _tp_size() == 0 else None)
    else:
        out = constrain(out.reshape(b, s, -1), "dp", None, hs)
    y = linear({"w": p["wo"]}, out)
    if not return_cache:
        return y
    clen = _cache_len(cache_len, s, op="attn_full")
    kc = jnp.zeros((b, clen, kh, hd), x.dtype).at[:, :s].set(k.astype(x.dtype))
    vc = jnp.zeros((b, clen, kh, hd), x.dtype).at[:, :s].set(v.astype(x.dtype))
    return y, {"k": kc, "v": vc}


def attn_decode(p, x, cfg, cache, pos, *, window=None):
    """Single-token decode. x: (B,1,D); cache k/v: (B,T,K,D).

    ``pos`` is a scalar int (rectangular serving: every row writes/attends at
    the same position) or a per-row (B,) int32 vector (continuous batching:
    each row sits at its own depth in its own sequence).
    """
    b, s, _ = x.shape
    hd = cfg.head_dim_
    kh, gh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1
    positions = pos[:, None] if per_row else jnp.full((b, s), pos, jnp.int32)
    q, k, v = _qk(p, x, cfg, positions)
    if per_row:
        rows = jnp.arange(b)
        kc = cache["k"].at[rows, pos].set(k[:, 0].astype(cache["k"].dtype))
        vc = cache["v"].at[rows, pos].set(v[:, 0].astype(cache["v"].dtype))
    else:
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, 1)
    # batch==1 (long-context): sequence-parallel cache; else batch over dp with
    # kv-heads over "model" — unless heads don't divide TP, in which case shard
    # the cache TIME axis (flash-decoding style partial softmax) to avoid the
    # contraction-sharded score all-reduce (§Perf I12).
    hs = _attn_head_spec(cfg)
    if b == 1:
        kc = constrain(kc, None, "data", "model" if hs else None, None)
        vc = constrain(vc, None, "data", "model" if hs else None, None)
    elif hs is not None:
        kc = constrain(kc, "dp", None, "model", None)
        vc = constrain(vc, "dp", None, "model", None)
    else:
        kc = constrain(kc, "dp", "model", None, None)
        vc = constrain(vc, "dp", "model", None, None)
    t = kc.shape[1]
    qg = q.reshape(b, s, kh, gh, hd)
    scores = _gqa_scores(qg, kc, hd ** -0.5, cfg.attn_softcap)    # (B,K,G,1,T)
    j = jnp.arange(t)
    if per_row:
        mask = j[None, :] <= pos[:, None]
        if window is not None:
            mask &= j[None, :] > (pos[:, None] - window)
        mask = mask[:, None, None, None, :]
    else:
        mask = j <= pos
        if window is not None:
            mask &= j > (pos - window)
        mask = mask[None, None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, vc).astype(x.dtype).reshape(b, s, -1)
    y = linear({"w": p["wo"]}, out)
    return y, {"k": kc, "v": vc}


def attn_decode_paged(p, x, cfg, cache, pos, *, window=None):
    """Single-token decode against a paged KV cache (continuous batching).

    ``cache``: ``{"k"/"v": (P, page, K, D)}`` physical page pools shared by
    every row, plus ``"pages": (B, nblk)`` int32 per-row page tables mapping
    logical block ``t // page`` to a pool page.  ``pos``: per-row (B,) int32
    write positions.  The new k/v land in page ``pages[b, pos//page]`` at
    slot ``pos % page``; attention then gathers each row's pages back into a
    contiguous ``(B, nblk*page, K, D)`` view and proceeds exactly like the
    dense path — same scores, same ``-1e30`` mask, same softmax — so for
    equal attention length T the result is bitwise identical to
    :func:`attn_decode` (rule 11 parity contract).  Page id 0 is the
    allocator's reserved scratch page: rows whose table entries are
    unassigned write there and never read it back.
    """
    b, s, _ = x.shape
    hd = cfg.head_dim_
    kh, gh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((b,), pos, jnp.int32)
    pages = cache["pages"]
    page = cache["k"].shape[1]
    q, k, v = _qk(p, x, cfg, pos[:, None])
    rows = jnp.arange(b)
    pid = pages[rows, pos // page]
    slot = pos % page
    kc = cache["k"].at[pid, slot].set(k[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[pid, slot].set(v[:, 0].astype(cache["v"].dtype))
    kv_k = kc[pages].reshape(b, -1, kh, hd)            # (B, nblk*page, K, D)
    kv_v = vc[pages].reshape(b, -1, kh, hd)
    t = kv_k.shape[1]
    qg = q.reshape(b, s, kh, gh, hd)
    scores = _gqa_scores(qg, kv_k, hd ** -0.5, cfg.attn_softcap)
    j = jnp.arange(t)
    mask = j[None, :] <= pos[:, None]
    if window is not None:
        mask &= j[None, :] > (pos[:, None] - window)
    scores = jnp.where(mask[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, kv_v).astype(x.dtype).reshape(b, s, -1)
    y = linear({"w": p["wo"]}, out)
    return y, {"k": kc, "v": vc, "pages": pages}


def attn_cross_decode(p, x, cfg, enc_cache):
    """Cross-attention during decode: enc k/v precomputed at prefill."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    kh, gh = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q = _split_heads(linear({"w": p["wq"]}, x), cfg.n_heads, hd)
    qg = q.reshape(b, s, kh, gh, hd)
    scores = _gqa_scores(qg, enc_cache["k"], hd ** -0.5, cfg.attn_softcap)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, enc_cache["v"]).astype(x.dtype).reshape(b, s, -1)
    return linear({"w": p["wo"]}, out)


def cross_kv(p, enc_out, cfg):
    hd = cfg.head_dim_
    k = _split_heads(linear({"w": p["wk"]}, enc_out), cfg.n_kv_heads, hd)
    v = _split_heads(linear({"w": p["wv"]}, enc_out), cfg.n_kv_heads, hd)
    return {"k": k.astype(enc_out.dtype), "v": v.astype(enc_out.dtype)}


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, minicpm3/deepseek style)
# ---------------------------------------------------------------------------


def mla_init(key, cfg, dtype=jnp.float32):
    m = cfg.mla
    h = cfg.n_heads
    ks = jax.random.split(key, 6)
    dqk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "q_a": ninit(ks[0], (cfg.d_model, m.q_lora_rank), dtype=dtype),
        "q_a_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "q_b": ninit(ks[1], (m.q_lora_rank, h * dqk), dtype=dtype),
        "kv_a": ninit(ks[2], (cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim),
                      dtype=dtype),
        "kv_a_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "kv_b": ninit(ks[3], (m.kv_lora_rank,
                              h * (m.qk_nope_head_dim + m.v_head_dim)), dtype=dtype),
        "wo": ninit(ks[4], (h * m.v_head_dim, cfg.d_model), dtype=dtype),
    }


def _mla_qkv_latent(p, x, cfg, positions):
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    qa = rmsnorm(p["q_a_norm"], linear({"w": p["q_a"]}, x), cfg.norm_eps)
    q = linear({"w": p["q_b"]}, qa).reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    kv = linear({"w": p["kv_a"]}, x)
    latent, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    latent = rmsnorm(p["kv_a_norm"], latent, cfg.norm_eps)
    k_rope = k_rope[:, :, None, :]                     # (B,S,1,dr) shared head
    if positions is not None:
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, latent, k_rope


def mla_full(p, x, cfg, *, positions=None, return_cache=False, cache_len=None):
    """Naive (expanded) MLA for train/prefill."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    q_nope, q_rope, latent, k_rope = _mla_qkv_latent(p, x, cfg, positions)
    kvb = linear({"w": p["kv_b"]}, latent).reshape(
        b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_head_dim], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshd,bthd->bhst", q_nope.astype(F32), k_nope.astype(F32))
              + jnp.einsum("bshd,btkd->bhst", q_rope.astype(F32),
                           k_rope[:, :, 0:1].astype(F32))) * scale
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    scores = jnp.where(j <= i, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(F32)).astype(x.dtype)
    y = linear({"w": p["wo"]}, out.reshape(b, s, -1))
    if not return_cache:
        return y
    clen = _cache_len(cache_len, s, op="mla_full")
    lat_c = jnp.zeros((b, clen, m.kv_lora_rank), x.dtype).at[:, :s].set(
        latent.astype(x.dtype))
    kr_c = jnp.zeros((b, clen, m.qk_rope_head_dim), x.dtype).at[:, :s].set(
        k_rope[:, :, 0].astype(x.dtype))
    return y, {"latent": lat_c, "k_rope": kr_c}


def mla_decode(p, x, cfg, cache, pos):
    """Absorbed-matrix MLA decode: attention runs in the latent space, so the cache
    stays compressed ((r + dr) per token instead of 2·H·hd) — the memory-roofline win
    that motivates MLA."""
    m, h = cfg.mla, cfg.n_heads
    b, s, _ = x.shape
    positions = jnp.full((b, s), pos, jnp.int32)
    q_nope, q_rope, latent, k_rope = _mla_qkv_latent(p, x, cfg, positions)
    lat_c = jax.lax.dynamic_update_slice_in_dim(
        cache["latent"], latent.astype(cache["latent"].dtype), pos, 1)
    kr_c = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype), pos, 1)
    wub = p["kv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wub[:, :, :m.qk_nope_head_dim]              # (r, H, dn)
    w_uv = wub[:, :, m.qk_nope_head_dim:]              # (r, H, dv)
    q_eff = jnp.einsum("bshd,rhd->bshr", q_nope.astype(F32), w_uk.astype(F32))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshr,btr->bhst", q_eff, lat_c.astype(F32))
              + jnp.einsum("bshd,btd->bhst", q_rope.astype(F32),
                           kr_c.astype(F32))) * scale
    mask = jnp.arange(lat_c.shape[1]) <= pos
    scores = jnp.where(mask[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs, lat_c.astype(F32))
    out = jnp.einsum("bshr,rhv->bshv", ctx, w_uv.astype(F32)).astype(x.dtype)
    y = linear({"w": p["wo"]}, out.reshape(b, s, -1))
    return y, {"latent": lat_c, "k_rope": kr_c}
