"""Model zoo — transformer/mamba/xlstm blocks built on the scan core."""
