"""xLSTM blocks: mLSTM (matrix memory — parallelised with the chunked matmul scan)
and sLSTM (scalar memory with recurrent weight mixing — *not* associative, so it runs
as a sequential ``lax.scan``; documented paper-technique inapplicability, DESIGN §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linrec import linear_scan
from repro.core.ssd import mlstm_chunked
from repro.models.layers import linear, ninit, rmsnorm, rmsnorm_init
from repro.models.mamba import _causal_conv

F32 = jnp.float32


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def mlstm_block_init(key, cfg, dtype=jnp.float32):
    x = cfg.xlstm
    d = cfg.d_model
    d_inner = int(x.proj_factor * d)
    ks = jax.random.split(key, 9)
    return {
        "in_proj": ninit(ks[0], (d, 2 * d_inner), dtype=dtype),   # (x_in, z)
        "conv_w": ninit(ks[1], (x.conv_kernel, d_inner), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "wq": ninit(ks[2], (d_inner, d_inner), dtype=dtype),
        "wk": ninit(ks[3], (d_inner, d_inner), dtype=dtype),
        "wv": ninit(ks[4], (d_inner, d_inner), dtype=dtype),
        "w_if": ninit(ks[5], (d_inner, 2 * x.n_heads), scale=0.01, dtype=dtype),
        "if_bias": jnp.concatenate([jnp.zeros((x.n_heads,)),
                                    jnp.linspace(3.0, 6.0, x.n_heads)]).astype(dtype),
        "skip": jnp.ones((d_inner,), dtype),
        "out_norm": rmsnorm_init(d_inner, dtype),
        "out_proj": ninit(ks[6], (d_inner, d), dtype=dtype),
    }


def _mlstm_qkvif(p, x, cfg, conv_cache=None):
    xl = cfg.xlstm
    d_inner = int(xl.proj_factor * cfg.d_model)
    b, s, _ = x.shape
    xin, z = jnp.split(linear({"w": p["in_proj"]}, x), 2, axis=-1)
    conv_out, conv_cache = _causal_conv(xin, p["conv_w"].astype(x.dtype),
                                        p["conv_b"].astype(x.dtype), cache=conv_cache)
    xc = jax.nn.silu(conv_out)
    hd = d_inner // xl.n_heads
    q = linear({"w": p["wq"]}, xc).reshape(b, s, xl.n_heads, hd)
    k = linear({"w": p["wk"]}, xc).reshape(b, s, xl.n_heads, hd)
    v = linear({"w": p["wv"]}, xin).reshape(b, s, xl.n_heads, hd)
    gates = linear({"w": p["w_if"]}, xin).astype(F32) + p["if_bias"].astype(F32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)        # (B,S,H)
    return q, k, v, i_pre, f_pre, xc, z, conv_cache


def mlstm_block(p, x, cfg, *, return_cache=False):
    xl = cfg.xlstm
    b, s, _ = x.shape
    d_inner = int(xl.proj_factor * cfg.d_model)
    q, k, v, i_pre, f_pre, xc, z, conv_cache = _mlstm_qkvif(p, x, cfg)
    h = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=128,
                      scan_method=cfg.scan_method)
    h = h.reshape(b, s, d_inner) + p["skip"].astype(x.dtype) * xc
    h = rmsnorm(p["out_norm"], h, cfg.norm_eps)
    out = linear({"w": p["out_proj"]}, h * jax.nn.silu(z))
    if not return_cache:
        return out
    hd = d_inner // xl.n_heads
    # stepwise decode state: matrix memory C, normaliser n, running max m
    kf = k.astype(F32) / jnp.sqrt(hd)
    flog = jax.nn.log_sigmoid(f_pre)
    # reconstruct the exact end-of-sequence stabilised state by replay (prefill only)
    def step(carry, t):
        c, n, m = carry
        kt, vt, it, ft = t
        m_new = jnp.maximum(ft + m, it)
        fs = jnp.exp(ft + m - m_new)
        is_ = jnp.exp(it - m_new)
        c = fs[..., None, None] * c + is_[..., None, None] * jnp.einsum(
            "bhd,bhp->bhdp", kt, vt)
        n = fs[..., None] * n + is_[..., None] * kt
        return (c, n, m_new), None
    init = (jnp.zeros((b, xl.n_heads, hd, hd), F32),
            jnp.zeros((b, xl.n_heads, hd), F32),
            jnp.full((b, xl.n_heads), -1e30, F32))
    xs = (jnp.moveaxis(kf, 1, 0), jnp.moveaxis(v.astype(F32), 1, 0),
          jnp.moveaxis(i_pre, 1, 0), jnp.moveaxis(flog, 1, 0))
    (c, n, m), _ = jax.lax.scan(step, init, xs)
    return out, {"conv": conv_cache, "c": c, "n": n, "m": m}


def mlstm_block_step(p, x, cfg, cache):
    """Single-token decode with the official running-max stabilisation.

    The gated cell/normaliser updates ``C = f·C + i·k v^T`` and
    ``n = f·n + i·k`` are one joint length-1 linear recurrence (the
    normaliser rides along as an extra memory column), routed through
    :func:`repro.core.linrec.linear_scan` under ``cfg.scan_method`` — the
    same dispatch surface as prefill (length-1 scans short-circuit to the
    direct fused multiply-add, bit-identical for every method).
    """
    xl = cfg.xlstm
    b = x.shape[0]
    d_inner = int(xl.proj_factor * cfg.d_model)
    hd = d_inner // xl.n_heads
    q, k, v, i_pre, f_pre, xc, z, conv_cache = _mlstm_qkvif(
        p, x, cfg, conv_cache=cache["conv"])
    qt = q[:, 0].astype(F32) / jnp.sqrt(hd)
    kt = k[:, 0].astype(F32) / jnp.sqrt(hd)
    vt = v[:, 0].astype(F32)
    it, ft = i_pre[:, 0], jax.nn.log_sigmoid(f_pre[:, 0])
    c, n, m = cache["c"], cache["n"], cache["m"]
    m_new = jnp.maximum(ft + m, it)
    fs = jnp.exp(ft + m - m_new)
    is_ = jnp.exp(it - m_new)
    # Joint state (C | n): (B,H,D,P+1); the decay fs multiplies both, the
    # update is (i·k v^T | i·k).  One linear_scan step updates the pair.
    cn = jnp.concatenate([c, n[..., None]], axis=-1)
    upd = jnp.concatenate(
        [is_[..., None, None] * jnp.einsum("bhd,bhp->bhdp", kt, vt),
         (is_[..., None] * kt)[..., None]], axis=-1)
    cn = linear_scan(fs[..., None, None, None], upd[..., None], axis=-1,
                     method=cfg.scan_method, initial=cn)[..., 0]
    c, n = cn[..., :-1], cn[..., -1]
    num = jnp.einsum("bhd,bhdp->bhp", qt, c)
    den = jnp.einsum("bhd,bhd->bh", qt, n)
    h = (num / (jnp.abs(den) + 1e-6)[..., None]).reshape(b, 1, d_inner)
    h = h.astype(x.dtype) + p["skip"].astype(x.dtype) * xc
    h = rmsnorm(p["out_norm"], h, cfg.norm_eps)
    out = linear({"w": p["out_proj"]}, h * jax.nn.silu(z))
    return out, {"conv": conv_cache, "c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM block (sequential: recurrence is non-associative)
# ---------------------------------------------------------------------------


def slstm_block_init(key, cfg, dtype=jnp.float32):
    x = cfg.xlstm
    d = cfg.d_model
    hd = d // x.n_heads
    ks = jax.random.split(key, 8)
    d_ff = int(4 * d / 3)
    return {
        "conv_w": ninit(ks[0], (x.conv_kernel, d), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((d,), dtype),
        "w_in": ninit(ks[1], (d, 4 * d), dtype=dtype),            # z,i,f,o inputs
        "r": ninit(ks[2], (4, x.n_heads, hd, hd), scale=hd ** -0.5, dtype=dtype),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((2 * d,)),
             jnp.tile(jnp.linspace(3.0, 6.0, x.n_heads)[:, None], (1, hd)).ravel(),
             jnp.zeros((d,))]).astype(dtype),
        "out_norm": rmsnorm_init(d, dtype),
        "ff_up": ninit(ks[3], (d, 2 * d_ff), dtype=dtype),
        "ff_down": ninit(ks[4], (d_ff, d), dtype=dtype),
    }


def _slstm_scan(p, wx, cfg, state):
    """wx: (B,S,4d) input projections (pre-bias).  Sequential over S."""
    x = cfg.xlstm
    d = cfg.d_model
    hd = d // x.n_heads
    b, s, _ = wx.shape
    r = p["r"].astype(F32)                              # (4, H, hd, hd)
    bias = p["gate_bias"].astype(F32)

    def step(carry, wt):
        c, n, m, h = carry                              # (B,H,hd) each; m (B,H,hd)
        pre = wt + bias                                  # (B, 4d)
        pre = pre.reshape(b, 4, x.n_heads, hd)
        rh = jnp.einsum("bhd,ghde->bghe", h, r)          # recurrent mixing
        zt = jnp.tanh(pre[:, 0] + rh[:, 0])
        it = pre[:, 1] + rh[:, 1]                        # log-space input gate
        ft = jax.nn.log_sigmoid(pre[:, 2] + rh[:, 2])    # log forget gate
        ot = jax.nn.sigmoid(pre[:, 3] + rh[:, 3])
        m_new = jnp.maximum(ft + m, it)
        ci = jnp.exp(it - m_new)
        cf = jnp.exp(ft + m - m_new)
        c = cf * c + ci * zt
        n = cf * n + ci
        h_new = ot * c / (n + 1e-6)
        return (c, n, m_new, h_new), h_new

    (c, n, m, h), ys = jax.lax.scan(step, state, jnp.moveaxis(wx.astype(F32), 1, 0))
    return jnp.moveaxis(ys, 0, 1), (c, n, m, h)


def slstm_state_init(b, cfg):
    x = cfg.xlstm
    hd = cfg.d_model // x.n_heads
    z = jnp.zeros((b, x.n_heads, hd), F32)
    return (z, z, jnp.full((b, x.n_heads, hd), -1e30, F32), z)


def slstm_block(p, x, cfg, *, state=None, return_cache=False):
    b, s, _ = x.shape
    conv_cache = None if state is None else state.get("conv")
    st = slstm_state_init(b, cfg) if state is None else state["rec"]
    conv_out, conv_cache = _causal_conv(x, p["conv_w"].astype(x.dtype),
                                        p["conv_b"].astype(x.dtype),
                                        cache=conv_cache)
    xc = jax.nn.silu(conv_out)
    # z and o gates see the raw input; i and f see the conv path (xLSTM convention)
    wx = linear({"w": p["w_in"]}, x)
    wc = linear({"w": p["w_in"]}, xc)
    d = cfg.d_model
    wmix = jnp.concatenate([wx[..., :d], wc[..., d:3 * d], wx[..., 3 * d:]], axis=-1)
    ys, st = _slstm_scan(p, wmix, cfg, st)
    h = ys.reshape(b, s, d).astype(x.dtype)
    h = rmsnorm(p["out_norm"], h, cfg.norm_eps)
    up, gate = jnp.split(linear({"w": p["ff_up"]}, h), 2, axis=-1)
    out = linear({"w": p["ff_down"]}, up * jax.nn.gelu(gate, approximate=True))
    if return_cache:
        return out, {"conv": conv_cache, "rec": st}
    return out


def slstm_block_step(p, x, cfg, cache):
    return slstm_block(p, x, cfg, state=cache, return_cache=True)
