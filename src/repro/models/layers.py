"""Basic layers (explicit pytree params — no flax dependency)."""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils.sharding import constrain

_DT = threading.local()


def compute_dtype():
    return getattr(_DT, "dtype", jnp.bfloat16)


@contextlib.contextmanager
def use_compute_dtype(dt):
    prev = compute_dtype()
    _DT.dtype = jnp.dtype(dt)
    try:
        yield
    finally:
        _DT.dtype = prev


def ninit(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def linear_init(key, d_in, d_out, *, dtype=jnp.float32, scale=None):
    return {"w": ninit(key, (d_in, d_out), scale, dtype)}


def linear(p, x, cdt=None):
    # No f32 materialisation of the output: the TPU MXU accumulates bf16 matmuls
    # in f32 internally regardless, and a materialised f32 result DOUBLES the wire
    # bytes of every tensor-parallel all-reduce placed on it (§Perf I2).
    cdt = cdt or compute_dtype()
    w = p["w"].astype(cdt)
    return jnp.matmul(x.astype(cdt), w)


def rmsnorm_init(d, dtype=jnp.float32):
    return {"g": jnp.zeros((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["g"].astype(jnp.float32))
    return out.astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def embed_init(key, vocab, d, dtype=jnp.float32, scale=1.0):
    return {"embed": ninit(key, (vocab, d), scale=scale, dtype=dtype)}


def embed_lookup(p, tokens, cdt=None):
    cdt = cdt or compute_dtype()
    return jnp.take(p["embed"].astype(cdt), tokens, axis=0)


def unembed(p, x, cdt=None):
    cdt = cdt or compute_dtype()
    w = p["embed"].astype(cdt)
    return jnp.matmul(x.astype(cdt), w.T,
                      preferred_element_type=jnp.float32)


ACTS = {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "gelu_nogate": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu}


def mlp_init(key, d, d_ff, *, gated=True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_up": ninit(ks[0], (d, d_ff), dtype=dtype),
         "w_down": ninit(ks[1], (d_ff, d), dtype=dtype)}
    if gated:
        p["w_gate"] = ninit(ks[2], (d, d_ff), dtype=dtype)
    return p


def mlp(p, x, act="silu"):
    up = linear({"w": p["w_up"]}, x)
    if "w_gate" in p:
        gate = linear({"w": p["w_gate"]}, x)
        h = ACTS[act](gate) * up
    else:
        h = ACTS[act](up)
    h = constrain(h, "dp", None, "model")
    return linear({"w": p["w_down"]}, h)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * freqs                       # (B, S, D/2)
    if ang.ndim == 2:                                  # (S, D/2) -> (1, S, D/2)
        ang = ang[None]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq_len: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((seq_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)
