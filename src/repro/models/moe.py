"""Mixture-of-Experts layer with scan-based token dispatch.

The dispatch offsets (position-in-expert for every token/expert assignment) are an
**exclusive prefix sum over int8 one-hot masks** — exactly the paper's int8→int32
cube-unit mask-scan specialization (§4.3 / Fig. 9), running here through
``repro.core.scan`` on the MXU.  Experts shard over the "model" mesh axis (EP).

Routing uses ``jax.lax.top_k``: the paper itself reports (§5, Top-k) that its
scan-based top-k did *not* beat the baseline for k ≤ 4096 — our k is 1..6, so the
baseline operator is the faithful choice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.compat import shard_map

from repro.core.scan import scan as mm_scan
from repro.models.layers import ACTS, linear, ninit
from repro.utils.sharding import constrain

F32 = jnp.float32


def moe_init(key, cfg, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": ninit(ks[0], (d, m.n_experts), scale=d ** -0.5, dtype=dtype)},
        "experts": {
            "w_gate": ninit(ks[1], (m.n_experts, d, f), dtype=dtype),
            "w_up": ninit(ks[2], (m.n_experts, d, f), dtype=dtype),
            "w_down": ninit(ks[3], (m.n_experts, f, d), dtype=dtype),
        },
    }
    if m.n_shared:
        p["shared"] = {
            "w_gate": ninit(ks[4], (d, m.n_shared * f), dtype=dtype),
            "w_up": ninit(ks[4], (d, m.n_shared * f), dtype=dtype),
            "w_down": ninit(ks[4], (m.n_shared * f, d), dtype=dtype),
        }
    return p


def _ep_shard_map_available(t: int):
    """(mesh, dp_axes, ep_size) when the explicit-EP shard_map path applies."""
    from repro.utils.sharding import current_mesh, dp_axes
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    ep = mesh.shape["model"]
    dpa = dp_axes(mesh) or ()
    dp = 1
    for a in dpa:
        dp *= mesh.shape[a]
    if ep <= 1 or t % max(dp, 1):
        return None
    return mesh, dpa, ep


def moe_apply_ep(p, xt, cfg, probs, gate_vals, expert_idx, *, mesh, dpa,
                 scan_method, no_drop):
    """Explicit expert-parallel MoE via shard_map (EXPERIMENTS.md §Perf I9).

    Tokens are replicated over the "model" axis under the surrounding pjit, so
    per chip: route + paper-int8-mask-scan positions + local scatter into the
    (E, C, D) buffer — ZERO communication; each chip runs the FFN for its own
    E/ep experts; the combine is one bf16 psum of (T_local, D) over "model" per
    layer (the same volume as one Megatron TP all-reduce).  No GSPMD scatter
    lowering can intervene — this removed the 2.6 TB/chip all-gather of
    scatter indices that the auto-partitioned formulation produced.
    """
    m = cfg.moe
    e, k = m.n_experts, m.top_k
    t, d = xt.shape
    ep = mesh.shape["model"]
    e_per = e // ep
    assert e % ep == 0, (e, ep)

    def body(xl, gv, eidx, wg, wu, wd):
        tg = xl.shape[0]
        capacity = tg if no_drop else max(int(tg * k * m.capacity_factor / e), k)
        flat_e = eidx.reshape(-1)                                   # (Tg*K,)
        onehot8 = (flat_e[:, None] ==
                   jnp.arange(e)[None, :]).astype(jnp.int8)
        pos_all = mm_scan(onehot8, axis=0, exclusive=True, method=scan_method)
        position = jnp.take_along_axis(pos_all, flat_e[:, None], 1)[:, 0]
        keep = position < capacity
        sentinel = e * capacity
        dest = jnp.where(keep, flat_e * capacity + position, sentinel)
        src = jnp.repeat(xl, k, axis=0)
        buf = jnp.zeros((sentinel + 1, d), xl.dtype).at[dest].set(src)

        ej = jax.lax.axis_index("model")
        mine = jax.lax.dynamic_slice_in_dim(
            buf[:-1].reshape(e, capacity, d), ej * e_per, e_per, 0)
        hg = ACTS[cfg.act](jnp.einsum("ecd,edf->ecf", mine, wg[0],
                                      preferred_element_type=F32)).astype(xl.dtype)
        hu = jnp.einsum("ecd,edf->ecf", mine, wu[0],
                        preferred_element_type=F32).astype(xl.dtype)
        out = jnp.einsum("ecf,efd->ecd", hg * hu, wd[0],
                         preferred_element_type=F32).astype(xl.dtype)

        flat_out = jnp.concatenate(
            [out.reshape(e_per * capacity, d), jnp.zeros((1, d), xl.dtype)], 0)
        local_e = flat_e - ej * e_per
        is_mine = keep & (local_e >= 0) & (local_e < e_per)
        idx = jnp.where(is_mine, local_e * capacity + position,
                        e_per * capacity)
        gathered = flat_out[idx]                                    # (Tg*K, D)
        weighted = gathered.astype(F32) * gv.reshape(-1)[:, None]
        y_part = weighted.reshape(tg, k, d).sum(1).astype(xl.dtype)
        return jax.lax.psum(y_part, "model")

    from jax.sharding import PartitionSpec as P
    dspec = P(dpa if dpa else None, None)
    wspec = P(None, "model", None, None)          # leading fake dim for the slice
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(dspec, dspec, dspec, wspec, wspec, wspec),
        out_specs=dspec)
    wg = p["experts"]["w_gate"].astype(xt.dtype)[None]
    wu = p["experts"]["w_up"].astype(xt.dtype)[None]
    wd = p["experts"]["w_down"].astype(xt.dtype)[None]
    return fn(xt, gate_vals, expert_idx, wg, wu, wd)


def _dispatch_positions(eidx, n_experts, *, scan_method, mode):
    """Position-in-expert for every (group, assignment) — the paper's mask scan.

    ``eidx``: (G, Tg*K) int32 expert ids.  Two equivalent formulations:

    * ``"grouped"`` — the hand-rolled reshape bookkeeping: build the
      (G, Tg*K, E) one-hot and run a *batched* exclusive int8 mask scan per
      group along axis 1.
    * ``"segmented"`` — the packed-batch formulation: flatten every
      assignment into ONE (E, G*Tg*K) one-hot stream and run a single
      exclusive *segmented* scan with the group boundaries as CSR offsets
      (``repro.core.segmented.segment_scan``).  Offsets are exact int8→int32
      mask scans either way, so both modes are bit-identical; the segmented
      form is what generalizes to ragged groups.

    Returns (G, Tg*K) int32 positions.
    """
    g, tgk = eidx.shape
    if mode == "grouped":
        onehot8 = (eidx[..., None] ==
                   jnp.arange(n_experts)[None, None, :]).astype(jnp.int8)
        pos_all = mm_scan(onehot8, axis=1, exclusive=True, method=scan_method)
        return jnp.take_along_axis(pos_all, eidx[..., None], axis=2)[..., 0]
    from repro.core.segmented import segment_scan
    flat = eidx.reshape(g * tgk)
    oh8 = (flat[None, :] ==
           jnp.arange(n_experts)[:, None]).astype(jnp.int8)       # (E, G*Tg*K)
    offsets = jnp.arange(g + 1, dtype=jnp.int32) * tgk
    pos_all = segment_scan(oh8, offsets, exclusive=True, method=scan_method)
    pos = jnp.take_along_axis(pos_all, flat[None, :], axis=0)[0]
    return pos.reshape(g, tgk)


def _dp_groups(t: int) -> int:
    """Number of data-parallel dispatch groups (aligned to the dp sharding)."""
    from repro.utils.sharding import current_mesh, dp_axes
    mesh = current_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in (dp_axes(mesh) or ()):
        g *= mesh.shape[a]
    return g if (g > 1 and t % g == 0) else 1


def moe_apply(p, x, cfg, *, scan_method=None, no_drop=False,
              dispatch_mode="auto"):
    """x: (B,S,D) -> (B,S,D).  GROUP-LOCAL capacity dispatch with scan offsets.

    Distribution (EXPERIMENTS.md §Perf cell C): tokens are viewed as
    (G, T/G, D) groups aligned to the dp sharding; the paper's int8 mask scan and
    the dispatch scatter run *within* each group (no cross-shard sequential
    dependence), and the only cross-chip traffic is the (G: dp) → (E: model)
    reshard of the dispatched buffers — one all-to-all each way.  The naive
    global-scatter formulation made GSPMD all-gather a u32[T·K·E, D] scatter-index
    tensor: 2.6 TB/chip wire on deepseek-moe train_4k.

    ``dispatch_mode`` selects how the position-in-expert offsets are computed
    (see ``_dispatch_positions``): ``"segmented"`` runs one packed segmented
    scan with group boundaries as CSR offsets, ``"grouped"`` the original
    batched reshape formulation, and ``"auto"`` picks segmented on a single
    dispatch group (no dp sharding to respect) and grouped otherwise.  The
    two are bit-identical.

    ``no_drop=True`` (decode) sizes capacity so no token can overflow.
    """
    m = cfg.moe
    scan_method = scan_method or cfg.scan_method
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    router_logits = linear({"w": p["router"]["w"]}, xt).astype(F32)     # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)               # (T, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    ep_ctx = _ep_shard_map_available(t)
    if ep_ctx is not None and m.n_experts % ep_ctx[2] == 0:
        mesh, dpa, _ = ep_ctx
        y = moe_apply_ep(p, xt, cfg, probs, gate_vals.astype(xt.dtype),
                         expert_idx, mesh=mesh, dpa=dpa,
                         scan_method=scan_method, no_drop=no_drop).astype(F32)
        if m.n_shared:
            sh = p["shared"]
            hg = ACTS[cfg.act](linear({"w": sh["w_gate"]}, xt))
            hu = linear({"w": sh["w_up"]}, xt)
            y = y + linear({"w": sh["w_down"]}, hg * hu).astype(F32)
        aux = _load_balance_loss(probs, expert_idx, m.n_experts)
        return y.reshape(b, s, d).astype(x.dtype), aux

    g = _dp_groups(t)
    tg = t // g                                        # tokens per group
    capacity = max(int(tg * m.top_k * m.capacity_factor / m.n_experts), m.top_k)
    if no_drop:
        capacity = tg                                  # decode: never drop a token

    # ---- the paper's int8 mask scan, per group (dp-local) ----
    eidx = expert_idx.reshape(g, tg * m.top_k)                          # (G, Tg*K)
    if dispatch_mode == "auto":
        dispatch_mode = "segmented" if g == 1 else "grouped"
    position = _dispatch_positions(eidx, m.n_experts,
                                   scan_method=scan_method,
                                   mode=dispatch_mode)
    keep = position < capacity                                          # (G, Tg*K)
    sentinel = m.n_experts * capacity
    dest = jnp.where(keep, eidx * capacity + position, sentinel)

    xg = constrain(xt.reshape(g, tg, d), "dp", None, None)
    src = jnp.repeat(xg, m.top_k, axis=1)                               # (G,Tg*K,D)
    buf = jnp.zeros((g, sentinel + 1, d), xt.dtype)
    gi = jnp.arange(g)[:, None]
    buf = buf.at[gi, dest].set(src)                     # group-local scatter
    ex_in = buf[:, :-1].reshape(g, m.n_experts, capacity, d)
    ex_in = constrain(ex_in, "dp", "model", None, None)  # the dispatch all-to-all

    wg = p["experts"]["w_gate"].astype(xt.dtype)
    wu = p["experts"]["w_up"].astype(xt.dtype)
    wd = p["experts"]["w_down"].astype(xt.dtype)
    hg = ACTS[cfg.act](jnp.einsum("gecd,edf->gecf", ex_in, wg,
                                  preferred_element_type=F32)).astype(xt.dtype)
    hu = jnp.einsum("gecd,edf->gecf", ex_in, wu,
                    preferred_element_type=F32).astype(xt.dtype)
    ex_out = jnp.einsum("gecf,efd->gecd", hg * hu, wd,
                        preferred_element_type=F32).astype(xt.dtype)
    ex_out = constrain(ex_out, "dp", "model", None, None)

    flat_out = jnp.concatenate(
        [ex_out.reshape(g, sentinel, d),
         jnp.zeros((g, 1, d), xt.dtype)], axis=1)
    flat_out = constrain(flat_out, "dp", None, None)     # the combine all-to-all
    gathered = flat_out[gi, jnp.where(keep, dest, sentinel)]  # (G, Tg*K, D)
    weighted = gathered.astype(F32) * gate_vals.reshape(g, tg * m.top_k)[..., None]
    y = weighted.reshape(g, tg, m.top_k, d).sum(axis=2).reshape(t, d)

    if m.n_shared:
        sh = p["shared"]
        hg = ACTS[cfg.act](linear({"w": sh["w_gate"]}, xt))
        hu = linear({"w": sh["w_up"]}, xt)
        y = y + linear({"w": sh["w_down"]}, hg * hu).astype(F32)

    aux = _load_balance_loss(probs, expert_idx, m.n_experts)
    return y.reshape(b, s, d).astype(x.dtype), aux


def _load_balance_loss(probs, expert_idx, n_experts):
    """Switch-style auxiliary load-balancing loss."""
    onehot = jax.nn.one_hot(expert_idx[:, 0], n_experts, dtype=F32)
    frac_tokens = onehot.mean(axis=0)
    frac_probs = probs.mean(axis=0)
    return n_experts * jnp.sum(frac_tokens * frac_probs)
