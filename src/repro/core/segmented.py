"""Segmented & ragged scan subsystem: packed-batch operators on the matmul scan.

Every operator in :mod:`repro.core.primitives` runs over one flat array; this
module lifts them to *packed variable-length batches* — the layout that MoE
group dispatch, continuous-batching decode and ragged data pipelines all
reduce to.  A packed batch is CSR-style: a ``values`` array of ``n`` elements
holding every segment back to back, plus int32 ``offsets`` of shape
``(num_segments + 1,)`` with ``offsets[0] == 0`` and ``offsets[-1] == n``
(empty segments are simply repeated offsets).  :class:`SegmentedBatch` bundles
the pair as a pytree.

The foundation is :func:`segment_scan` — a prefix sum whose carry resets at
segment boundaries — dispatched through the same ``method=`` table as
:func:`repro.core.scan.scan`:

* ``"matmul"`` / ``"vector"`` — the full unsegmented scan (matmul or cumsum)
  followed by subtracting the gathered scan value at each element's segment
  start.  Exact for the integer mask scans the operators are built from, and
  for integer-valued floats (the repo-wide float-parity contract).
* ``"kernel"`` — the fused sequential-grid segmented kernel
  (:mod:`repro.kernels.segscan_mm`): boundary-flag masks folded into the
  ``A @ U_s`` contraction in-register, carry gated in SMEM.
* ``"blocked"`` — the §4 three-phase pipeline with a *segmented* phase-2
  carry scan, so multi-block ragged inputs still read/write each element once.

On top of it ride the packed-batch operators: :func:`segment_cumsum`,
:func:`segment_sums`, :func:`segment_compress`, :func:`segment_sort`,
:func:`segment_topk`, :func:`segment_softmax` and
:func:`segment_top_p_sample`.  Parity contract (enforced by
``tests/test_segmented.py``): every segmented op is bit-identical to looping
the corresponding 1-D op over each segment slice, for every registered
method — offsets, permutations and counts are exact int8 -> int32 mask
scans, so the contract holds for any payload; float *sums* follow the same
exactly-representable rule as the unsegmented methods.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guards
from repro.core.autotune import maybe_resolve
from repro.core.linrec import linear_scan, linrec_accum_dtype_for
from repro.core.precision import resolve_precision
from repro.core.primitives import _encode_for_sort, _register, dispatch
from repro.core.scan import accum_dtype_for, scan

__all__ = [
    "SegmentedBatch", "boundary_flags", "segment_ids", "segment_scan",
    "segment_cumsum", "segment_sums", "segment_softmax", "segment_compress",
    "segment_sort", "segment_topk", "segment_top_p_sample",
    "segment_linear_scan",
]


# ---------------------------------------------------------------------------
# The packed container
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SegmentedBatch:
    """CSR-style packed batch: ``values`` back to back, ``offsets`` framing them.

    ``offsets`` is int32 of shape ``(num_segments + 1,)`` with
    ``offsets[0] == 0`` and ``offsets[-1] == values.shape[-1]``; segment ``i``
    is ``values[offsets[i]:offsets[i + 1]]``.  Empty segments are repeated
    offsets; the container is a registered pytree, so it passes through
    ``jax.jit`` / ``jax.vmap`` boundaries like any array.

    Example:
        >>> import jax.numpy as jnp
        >>> sb = SegmentedBatch.from_ragged([[1, 2, 3], [], [4, 5]])
        >>> sb.num_segments, sb.lengths.tolist()
        (3, [3, 0, 2])
        >>> [seg.tolist() for seg in sb.to_ragged()]
        [[1, 2, 3], [], [4, 5]]
    """

    values: jax.Array
    offsets: jax.Array

    def tree_flatten(self):
        """Flatten into ``(values, offsets)`` leaves (no static aux data)."""
        return (self.values, self.offsets), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from the ``(values, offsets)`` leaves."""
        return cls(*children)

    @property
    def num_segments(self) -> int:
        """Number of segments (static: ``offsets.shape[0] - 1``)."""
        return self.offsets.shape[0] - 1

    @property
    def lengths(self) -> jax.Array:
        """Per-segment lengths, int32 of shape ``(num_segments,)``."""
        return (self.offsets[1:] - self.offsets[:-1]).astype(jnp.int32)

    @classmethod
    def from_ragged(cls, segments: Sequence, dtype=None) -> "SegmentedBatch":
        """Pack a host-side list of per-segment arrays into one batch.

        Args:
            segments: Sequence of 1-D array-likes (may include empties).
            dtype: Optional dtype for the packed values.

        Returns:
            A :class:`SegmentedBatch` with ``offsets[0] == 0``.
        """
        arrs = [np.asarray(s).reshape(-1) for s in segments]
        ref = next((a for a in arrs if a.size), None)
        if ref is not None:  # keep empties from promoting the concat dtype
            arrs = [a.astype(ref.dtype) if a.size == 0 else a for a in arrs]
        lens = np.asarray([a.shape[0] for a in arrs], np.int32)
        offsets = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
        if ref is not None:
            values = np.concatenate(arrs)
        else:
            values = np.zeros((0,), np.int32)
        if dtype is not None:
            values = values.astype(dtype)
        return cls(jnp.asarray(values), jnp.asarray(offsets))

    def to_ragged(self) -> List[np.ndarray]:
        """Unpack to a host-side list of per-segment numpy arrays."""
        v = np.asarray(self.values)
        off = np.asarray(self.offsets)
        return [v[off[i]:off[i + 1]] for i in range(self.num_segments)]

    def to_dense(self, fill_value=0) -> Tuple[np.ndarray, np.ndarray]:
        """Host-side conversion to a dense ``(num_segments, max_len)`` pair.

        Args:
            fill_value: Value for the ragged tails.

        Returns:
            ``(dense, mask)`` numpy arrays; ``mask`` is true on real elements.
        """
        segs = self.to_ragged()
        width = max((s.shape[0] for s in segs), default=0)
        dense = np.full((len(segs), width), fill_value,
                        dtype=np.asarray(self.values).dtype)
        mask = np.zeros((len(segs), width), bool)
        for i, s in enumerate(segs):
            dense[i, :s.shape[0]] = s
            mask[i, :s.shape[0]] = True
        return dense, mask


def _unwrap(values, offsets, *, op: str = "segmented"):
    """Accept either a :class:`SegmentedBatch` or a ``(values, offsets)`` pair.

    Offsets are validated on the way in (dispatch rule 10): static structure
    (rank, dtype) always, the full CSR contract eagerly when the offsets are
    concrete, and as a staged :func:`repro.core.guards.guard_check` assertion
    when they are traced — every packed-batch entry point shares this one
    choke point.
    """
    if isinstance(values, SegmentedBatch):
        values, offsets = values.values, values.offsets
    elif offsets is None:
        raise ValueError("offsets required when values is not a SegmentedBatch")
    else:
        offsets = jnp.asarray(offsets, jnp.int32)
    offsets = guards.validate_offsets(offsets, jnp.shape(values)[-1], op=op)
    return values, offsets


# ---------------------------------------------------------------------------
# Boundary structure (flags / ids / end gathers) — all scan-based
# ---------------------------------------------------------------------------


def boundary_flags(offsets: jax.Array, n: int) -> jax.Array:
    """Int8 flags marking segment starts: ``flags[i] = 1`` iff ``i`` starts one.

    Offsets equal to ``n`` (trailing empty segments) are dropped by the
    scatter; coinciding starts of empty segments collapse onto one flag.

    Args:
        offsets: ``(num_segments + 1,)`` int32 CSR offsets.
        n: Packed length ``offsets[-1]``.

    Returns:
        ``(n,)`` int8 array of {0, 1} boundary flags.

    Example:
        >>> import jax.numpy as jnp
        >>> boundary_flags(jnp.asarray([0, 2, 2, 5]), 5).tolist()
        [1, 0, 1, 0, 0]
    """
    return jnp.zeros((n,), jnp.int8).at[offsets[:-1]].set(1, mode="drop")


def segment_ids(offsets: jax.Array, n: int, *, method: str = "vector",
                tile_s: int = 128) -> jax.Array:
    """Segment id of every packed element, via a scan of the start counts.

    Scatter-adds one count per segment start (empty segments stack on the
    same index) and takes the inclusive prefix sum minus one — so even
    through empty segments each element maps to the segment that actually
    contains it.

    Args:
        offsets: ``(num_segments + 1,)`` int32 CSR offsets.
        n: Packed length ``offsets[-1]``.
        method: Scan method for the counting scan, one of ``METHODS``.
        tile_s: Tile side for the matmul scans.

    Returns:
        ``(n,)`` int32 ids in ``[0, num_segments)``.

    Example:
        >>> import jax.numpy as jnp
        >>> segment_ids(jnp.asarray([0, 2, 2, 5]), 5).tolist()
        [0, 0, 2, 2, 2]
    """
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    counts = jnp.zeros((n,), jnp.int32).at[offsets[:-1]].add(1, mode="drop")
    return scan(counts, method=method, tile_s=tile_s).astype(jnp.int32) - 1


def _segment_ends(per_element: jax.Array, offsets: jax.Array) -> jax.Array:
    """Gather a per-element array at each segment's last element (0 if empty).

    Used to read per-segment totals off an inclusive segmented scan.
    """
    n = per_element.shape[-1]
    num_segments = offsets.shape[0] - 1
    if n == 0:  # all segments empty: every total is zero
        return jnp.zeros(per_element.shape[:-1] + (num_segments,),
                         per_element.dtype)
    lens = offsets[1:] - offsets[:-1]
    ends = jnp.clip(offsets[1:] - 1, 0, n - 1)
    vals = jnp.take(per_element, ends, axis=-1)
    return jnp.where(lens > 0, vals, jnp.zeros((), per_element.dtype))


# ---------------------------------------------------------------------------
# segment_scan — the subsystem's foundation, method-dispatched
# ---------------------------------------------------------------------------


@_register("segment_scan", "matmul", "vector")
def _segment_scan_unfused(values, offsets, *, method, tile_s, block_tiles,
                          accum_dtype, precision="highest"):
    """Full unsegmented scan, then subtract the value at each segment start.

    ``seg[i] = scan(values)[i] - scan(values)[start(i) - 1]`` — the
    TCU-formulation correction step (Dakkak et al.); exact whenever the
    partial sums are exactly representable (all integer paths, integer-valued
    floats).
    """
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else accum_dtype_for(values.dtype)
    full = scan(values, axis=-1, method=method, tile_s=tile_s,
                block_tiles=block_tiles, accum_dtype=acc, precision=precision)
    n = values.shape[-1]
    ids = segment_ids(offsets, n)
    starts = jnp.take(offsets, ids)
    base = jnp.take(full, jnp.clip(starts - 1, 0, n - 1), axis=-1)
    return full - jnp.where(starts > 0, base, jnp.zeros((), acc))


@_register("segment_scan", "kernel")
def _segment_scan_fused(values, offsets, *, method, tile_s, block_tiles,
                        accum_dtype, precision="highest"):
    """Fused sequential-grid segmented kernel (one launch per batch row)."""
    from repro.kernels import ops as _kops
    flags = boundary_flags(offsets, values.shape[-1])
    return _kops.seg_scan_kernel(values, flags, s=tile_s,
                                 accum_dtype=accum_dtype, precision=precision)


@_register("segment_scan", "blocked")
def _segment_scan_blocked(values, offsets, *, method, tile_s, block_tiles,
                          accum_dtype, precision="highest"):
    """§4 blocked pipeline with the segmented phase-2 carry scan."""
    from repro.kernels import ops as _kops
    flags = boundary_flags(offsets, values.shape[-1])
    return _kops.seg_blocked_scan_kernel(values, flags, s=tile_s,
                                         block_tiles=block_tiles,
                                         accum_dtype=accum_dtype,
                                         precision=precision)


def segment_scan(values, offsets=None, *, exclusive: bool = False,
                 reverse: bool = False, method: str = "auto",
                 tile_s: int = 128, block_tiles: int = 8,
                 accum_dtype=None, precision: str = "highest",
                 nonfinite: str = "propagate") -> jax.Array:
    """Per-segment prefix sum of a packed batch — the carry resets at boundaries.

    The segmented analogue of :func:`repro.core.scan.scan`: same ``method=``
    dispatch, same accumulation-dtype rules (``int8 -> int32`` mask scans,
    ``bf16/f16 -> f32``), applied independently within every segment of the
    packed layout.  Leading batch dimensions share the same offsets (used by
    the one-hot mask scans of :func:`segment_sort` and MoE dispatch).

    Args:
        values: Packed array ``(..., n)`` — or a :class:`SegmentedBatch`
            (then ``offsets`` is taken from it).
        offsets: ``(num_segments + 1,)`` int32 CSR offsets framing the last
            axis; required unless ``values`` is a :class:`SegmentedBatch`.
        exclusive: Shift each segment's result right by one with a leading 0.
        reverse: Scan each segment from its end (per-segment suffix sums).
        method: One of ``METHODS`` (see module docstring for what runs).
        tile_s: Tile side ``s`` for the matmul scans.
        block_tiles: Tiles per block for ``method="blocked"``.
        accum_dtype: Accumulation dtype override.
        precision: Engine precision for the masked contractions —
            ``"highest"`` (default), ``"compensated"`` or ``"fast"``; see
            :mod:`repro.core.precision` (dispatch rule 9).  Integer mask
            scans stay exact under every precision.
        nonfinite: Non-finite input policy — ``"propagate"`` (default, IEEE
            semantics), ``"raise"`` or ``"sanitize"`` (non-finite elements
            become the additive identity 0); see
            :func:`repro.core.guards.resolve_nonfinite` (dispatch rule 10).

    Returns:
        The per-segment scanned array, same shape as ``values``, in the
        accumulation dtype.

    Raises:
        ValueError: If an explicit non-default ``precision`` is combined
            with an explicit ``method="vector"``, or the offsets break the
            CSR contract.
        repro.core.guards.NonFiniteError: Under ``nonfinite="raise"`` with
            a concrete non-finite payload.

    Example:
        >>> import jax.numpy as jnp
        >>> x = jnp.asarray([1, 1, 1, 1, 1], jnp.int32)
        >>> segment_scan(x, jnp.asarray([0, 2, 5])).tolist()
        [1, 2, 1, 2, 3]
        >>> segment_scan(x, jnp.asarray([0, 2, 5]), exclusive=True).tolist()
        [0, 1, 0, 1, 2]
    """
    values, offsets = _unwrap(values, offsets, op="segment_scan")
    values = guards.apply_nonfinite(
        values, guards.resolve_nonfinite(nonfinite), op="segment_scan")
    n = values.shape[-1]
    explicit_method = method != "auto"
    method = maybe_resolve(method, "segment_scan", n, values.dtype)
    precision = resolve_precision(precision, method=method,
                                  explicit_method=explicit_method)
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else accum_dtype_for(values.dtype)
    if n == 0:
        return jnp.zeros(values.shape, acc)
    if reverse:
        rev_off = (n - offsets)[::-1]
        out = segment_scan(jnp.flip(values, axis=-1), rev_off,
                           exclusive=exclusive, method=method, tile_s=tile_s,
                           block_tiles=block_tiles, accum_dtype=accum_dtype,
                           precision=precision)
        return jnp.flip(out, axis=-1)
    out = dispatch("segment_scan", method)(
        values, offsets, method=method, tile_s=tile_s,
        block_tiles=block_tiles, accum_dtype=accum_dtype,
        precision=precision)
    if exclusive:
        pad = [(0, 0)] * (out.ndim - 1) + [(1, 0)]
        shifted = jnp.pad(out, pad)[..., :-1]
        out = jnp.where(boundary_flags(offsets, n) > 0,
                        jnp.zeros((), out.dtype), shifted)
    return out


def segment_cumsum(values, offsets=None, **kw) -> jax.Array:
    """Drop-in per-segment ``cumsum`` — alias of :func:`segment_scan`.

    Args:
        values: Packed array ``(..., n)`` or a :class:`SegmentedBatch`.
        offsets: CSR offsets (unless ``values`` is a batch).
        **kw: Forwarded to :func:`segment_scan` (``method=``, ``exclusive=``,
            …).

    Returns:
        Per-segment inclusive (or exclusive) prefix sums.

    Example:
        >>> import jax.numpy as jnp
        >>> segment_cumsum(jnp.asarray([3, 4, 5]), jnp.asarray([0, 1, 3])).tolist()
        [3, 4, 9]
    """
    return segment_scan(values, offsets, **kw)


def segment_linear_scan(a, b, offsets=None, *, exclusive: bool = False,
                        reverse: bool = False, method: str = "auto",
                        initial=0.0, tile_s: int = 128, block_tiles: int = 8,
                        accum_dtype=None, precision: str = "highest",
                        nonfinite: str = "propagate") -> jax.Array:
    """Per-segment linear recurrence ``y_t = a_t * y_{t-1} + b_t`` of a packed batch.

    The segmented analogue of :func:`repro.core.linrec.linear_scan`: at every
    segment boundary the carry resets to ``initial``.  The reset is the same
    masked-contraction trick as ``segscan_mm`` — zeroing ``a`` at flagged
    positions (and folding ``a_t * initial`` into ``b``) kills exactly the
    ``W[i, j]`` entries whose window straddles a boundary, so the packed batch
    runs as ONE unsegmented ``linear_scan`` under whichever ``method=`` is
    requested, with no extra kernel.  Exactness matches the unsegmented
    contract (true zeros of ``a`` are handled exactly by the weighted
    triangle).

    Args:
        a: Packed multipliers ``(..., n)`` — or a :class:`SegmentedBatch`
            (then ``offsets`` is taken from it); broadcast against ``b``.
        b: Packed additive inputs ``(..., n)``, broadcast against ``a``.
        offsets: ``(num_segments + 1,)`` int32 CSR offsets framing the last
            axis; required unless ``a`` is a :class:`SegmentedBatch`.
        exclusive: Return the state entering each step; segment starts get
            ``initial``.
        reverse: Scan each segment from its end.
        method: One of ``METHODS`` — forwarded to ``linear_scan``.
        initial: State the carry resets to at each segment start — a scalar,
            or an array broadcastable against the leading (batch) dims of
            ``a``/``b`` (it is aligned against the packed axis internally, so
            a ``(batch,)`` initial applies per row).
        tile_s: Tile side for the matmul scans.
        block_tiles: Tiles per block for ``method="blocked"``.
        accum_dtype: Accumulation dtype override.
        precision: Engine precision, forwarded to the underlying
            :func:`repro.core.linrec.linear_scan` (dispatch rule 9).
        nonfinite: Non-finite input policy (dispatch rule 10) —
            ``"sanitize"`` maps non-finite elements to the affine identity
            (``a -> 1``, ``b -> 0``).

    Returns:
        The per-segment recurrence, broadcast shape of ``a``/``b``, in the
        linrec accumulation dtype.

    Raises:
        ValueError: If an explicit non-default ``precision`` is combined
            with an explicit ``method="vector"``, or the offsets break the
            CSR contract.
        repro.core.guards.NonFiniteError: Under ``nonfinite="raise"`` with
            concrete non-finite coefficients.

    Example:
        >>> import jax.numpy as jnp
        >>> a = jnp.asarray([2.0, 2.0, 2.0, 2.0, 2.0])
        >>> b = jnp.ones(5)
        >>> segment_linear_scan(a, b, jnp.asarray([0, 2, 5])).tolist()
        [1.0, 3.0, 1.0, 3.0, 7.0]
        >>> segment_linear_scan(a, b, jnp.asarray([0, 2, 5]),
        ...                     initial=1.0).tolist()
        [3.0, 7.0, 3.0, 7.0, 15.0]
    """
    a, offsets = _unwrap(a, offsets, op="segment_linear_scan")
    nf = guards.resolve_nonfinite(nonfinite)
    a = guards.apply_nonfinite(a, nf, op="segment_linear_scan", identity=1.0)
    b = guards.apply_nonfinite(b, nf, op="segment_linear_scan", identity=0.0)
    shp = jnp.broadcast_shapes(a.shape, b.shape)
    a = jnp.broadcast_to(a, shp)
    b = jnp.broadcast_to(b, shp)
    n = a.shape[-1]
    explicit_method = method != "auto"
    method = maybe_resolve(method, "segment_linear_scan", n,
                           jnp.result_type(a.dtype, b.dtype))
    precision = resolve_precision(precision, method=method,
                                  explicit_method=explicit_method)
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else linrec_accum_dtype_for(jnp.result_type(a.dtype, b.dtype))
    if n == 0:
        return jnp.zeros(shp, acc)
    if reverse:
        rev_off = (n - offsets)[::-1]
        out = segment_linear_scan(
            jnp.flip(a, axis=-1), jnp.flip(b, axis=-1), rev_off,
            exclusive=exclusive, method=method, initial=initial,
            tile_s=tile_s, block_tiles=block_tiles, accum_dtype=accum_dtype,
            precision=precision)
        return jnp.flip(out, axis=-1)
    flags = boundary_flags(offsets, n) > 0
    init = jnp.asarray(initial, acc)
    # align an array initial with the *leading* dims: the packed axis is the
    # last one, so a per-batch-row initial needs a trailing length-1 axis.
    init_e = init[..., None] if init.ndim else init
    a_cut = jnp.where(flags, jnp.zeros((), acc), a.astype(acc))
    b_cut = jnp.where(flags, b.astype(acc) + a.astype(acc) * init_e,
                      b.astype(acc))
    out = linear_scan(a_cut, b_cut, method=method, tile_s=tile_s,
                      block_tiles=block_tiles, accum_dtype=acc,
                      precision=precision)
    if exclusive:
        pad = [(0, 0)] * (out.ndim - 1) + [(1, 0)]
        shifted = jnp.pad(out, pad)[..., :-1]
        out = jnp.where(flags, jnp.broadcast_to(init_e, out.shape), shifted)
    return out


def segment_sums(values, offsets=None, *, method: str = "auto",
                 tile_s: int = 128, block_tiles: int = 8,
                 accum_dtype=None, precision: str = "highest") -> jax.Array:
    """Per-segment totals, read off the inclusive segmented scan's last element.

    Args:
        values: Packed array ``(..., n)`` or a :class:`SegmentedBatch`.
        offsets: CSR offsets (unless ``values`` is a batch).
        method: Scan method, one of ``METHODS``.
        tile_s: Tile side for the matmul scans.
        block_tiles: Tiles per block for ``method="blocked"``.
        accum_dtype: Accumulation dtype override.
        precision: Engine precision, forwarded to :func:`segment_scan`.

    Returns:
        ``(..., num_segments)`` totals in the accumulation dtype (0 for empty
        segments).

    Example:
        >>> import jax.numpy as jnp
        >>> segment_sums(jnp.ones(5, jnp.int8), jnp.asarray([0, 2, 2, 5])).tolist()
        [2, 0, 3]
    """
    values, offsets = _unwrap(values, offsets)
    inc = segment_scan(values, offsets, method=method, tile_s=tile_s,
                       block_tiles=block_tiles, accum_dtype=accum_dtype,
                       precision=precision)
    return _segment_ends(inc, offsets)


# ---------------------------------------------------------------------------
# segment_compress — ragged tensor masking (per-segment SplitInd)
# ---------------------------------------------------------------------------


@_register("segment_compress", *("matmul", "vector", "kernel", "blocked"))
def _segment_compress_impl(values, mask, offsets, *, method, fill_value,
                           tile_s, block_tiles):
    """Per-segment masked select via one segmented int8 mask scan + scatter."""
    n = values.shape[-1]
    ids = segment_ids(offsets, n)
    seg_start = jnp.take(offsets, ids)
    ex = segment_scan(mask.astype(jnp.int8), offsets, exclusive=True,
                      method=method, tile_s=tile_s, block_tiles=block_tiles)
    inc = ex + mask.astype(jnp.int32)
    counts = _segment_ends(inc, offsets)
    pos_in_seg = jnp.arange(n, dtype=jnp.int32) - seg_start
    pos_false = pos_in_seg - ex
    dest = seg_start + jnp.where(mask, ex, jnp.take(counts, ids) + pos_false)
    z = jnp.zeros_like(values).at[dest].set(values)
    keep = pos_in_seg < jnp.take(counts, ids)
    z = jnp.where(keep, z, jnp.asarray(fill_value, z.dtype))
    return z, counts


def segment_compress(values, mask, offsets=None, *, method: str = "auto",
                     fill_value=0, tile_s: int = 128,
                     block_tiles: int = 8) -> Tuple[jax.Array, jax.Array]:
    """Per-segment masked select: within each segment, kept elements pack left.

    The segmented analogue of :func:`repro.core.primitives.compress` — the
    destination offsets are an exclusive *segmented* int8 mask scan, so each
    segment behaves exactly like an independent 1-D ``compress`` while the
    whole packed batch runs in one pass.

    Args:
        values: Packed payload ``(n,)`` or a :class:`SegmentedBatch`.
        mask: Boolean ``(n,)``; true elements pack to their segment's front.
        offsets: CSR offsets (unless ``values`` is a batch).
        method: One of ``METHODS``.
        fill_value: Fill for every segment's dropped tail.
        tile_s: Tile side for the mask scans.
        block_tiles: Tiles per block for ``method="blocked"``.

    Returns:
        ``(packed, counts)`` — ``packed`` has the same shape as ``values``
        with each segment's kept elements first and its tail filled;
        ``counts`` is ``(num_segments,)`` int32 kept-counts.

    Example:
        >>> import jax.numpy as jnp
        >>> v = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
        >>> m = jnp.asarray([False, True, True, False, True])
        >>> z, c = segment_compress(v, m, jnp.asarray([0, 2, 5]))
        >>> z.tolist(), c.tolist()
        ([2, 0, 3, 5, 0], [1, 2])
    """
    values, offsets = _unwrap(values, offsets)
    method = maybe_resolve(method, "segment_compress", values.shape[-1],
                           values.dtype)
    return dispatch("segment_compress", method)(
        values, mask, offsets, method=method, fill_value=fill_value,
        tile_s=tile_s, block_tiles=block_tiles)


# ---------------------------------------------------------------------------
# segment_sort / segment_topk — per-segment radix passes, one packed launch set
# ---------------------------------------------------------------------------


def _segment_multi_split_dest(digits, num_buckets, offsets, ids, seg_start, *,
                              method, tile_s, block_tiles):
    """Destination offsets for a stable in-segment ``num_buckets``-way split.

    The segmented analogue of ``primitives._multi_split_dest``: all ``R``
    bucket mask scans run as one batched *segmented* int8 -> int32 scan
    (leading bucket dimension, shared offsets), per-(segment, bucket) bases
    come from a tiny ``R``-wide exclusive prefix of the per-segment bucket
    counts, and every destination stays inside its own segment.
    """
    d32 = digits.astype(jnp.int32)
    buckets = jnp.arange(num_buckets, dtype=jnp.int32)
    oh = (d32[None, :] == buckets[:, None]).astype(jnp.int8)      # (R, n)
    ex = segment_scan(oh, offsets, exclusive=True, method=method,
                      tile_s=tile_s, block_tiles=block_tiles)
    inc = ex + oh.astype(jnp.int32)
    counts = _segment_ends(inc, offsets)                          # (R, S)
    base = jnp.cumsum(counts, axis=0) - counts                    # R-wide scan
    ex_el = jnp.take_along_axis(ex, d32[None, :], axis=0)[0]
    dest = seg_start + base[d32, ids] + ex_el
    return dest, counts


def segment_sort(values, offsets=None, *, descending: bool = False,
                 method: str = "auto", bits_per_pass: int = 4,
                 return_indices: bool = True, tile_s: int = 128,
                 block_tiles: int = 8):
    """Stable per-segment radix sort of a packed batch — one pass set for all.

    Each radix pass is a stable in-segment ``2^bits_per_pass``-way split:
    elements never leave their segment, so after ``ceil(bits / k)`` passes
    every segment is independently sorted — bit-identical to running
    :func:`repro.core.primitives.radix_sort` on each segment slice, for every
    ``method`` (bucket offsets are exact segmented int8 -> int32 mask scans).

    Args:
        values: Packed keys ``(n,)`` or a :class:`SegmentedBatch` (dtypes as
            in :func:`repro.core.primitives.radix_sort`).
        offsets: CSR offsets (unless ``values`` is a batch).
        descending: Sort each segment high-to-low (stability preserved).
        method: One of ``METHODS``.
        bits_per_pass: Bits retired per radix pass (``1..8``).
        return_indices: If false, return only the sorted values.
        tile_s: Tile side for the mask scans.
        block_tiles: Tiles per block for ``method="blocked"``.

    Returns:
        ``(sorted_values, indices)`` — or just ``sorted_values`` — where
        ``indices`` are int32 positions into the *packed* array
        (``sorted_values == values[indices]``; subtract ``offsets[seg]`` for
        segment-local ranks).

    Raises:
        ValueError: If ``bits_per_pass`` is outside ``[1, 8]``.

    Example:
        >>> import jax.numpy as jnp
        >>> v, i = segment_sort(jnp.asarray([3, 1, 9, 2, 5], jnp.int32),
        ...                     jnp.asarray([0, 2, 5]))
        >>> v.tolist(), i.tolist()
        ([1, 3, 2, 5, 9], [1, 0, 3, 4, 2])
    """
    bits_per_pass = guards.validate_bits_per_pass(bits_per_pass,
                                                  op="segment_sort")
    values, offsets = _unwrap(values, offsets, op="segment_sort")
    if values.ndim != 1:
        raise ValueError("segment_sort expects 1-D packed values")
    n = values.shape[-1]
    method = maybe_resolve(method, "segment_sort", n, values.dtype)
    enc, bits, decode = _encode_for_sort(values)
    if descending:
        enc = ~enc
    ids = segment_ids(offsets, n)
    seg_start = jnp.take(offsets, ids)
    perm = jnp.arange(n, dtype=jnp.int32)
    for shift in range(0, bits, bits_per_pass):
        k = min(bits_per_pass, bits - shift)
        mask = jnp.asarray((1 << k) - 1, enc.dtype)
        digits = ((enc >> shift) & mask).astype(jnp.int32)
        dest, _ = _segment_multi_split_dest(
            digits, 1 << k, offsets, ids, seg_start, method=method,
            tile_s=tile_s, block_tiles=block_tiles)
        enc = jnp.zeros_like(enc).at[dest].set(enc)
        perm = jnp.zeros_like(perm).at[dest].set(perm)
    if descending:
        enc = ~enc
    sorted_values = decode(enc)
    if return_indices:
        return sorted_values, perm
    return sorted_values


def segment_topk(values, offsets=None, k: int = 1, *, method: str = "auto",
                 bits_per_pass: int = 4, fill_value=0, tile_s: int = 128,
                 block_tiles: int = 8):
    """Per-segment top-k of a packed batch via one descending segmented sort.

    Segments shorter than ``k`` return their full (sorted) contents; the
    output is dense ``(num_segments, k)`` with ragged tails filled, plus the
    per-segment valid counts — the static-shape convention of the 1-D
    operators.

    Args:
        values: Packed keys ``(n,)`` or a :class:`SegmentedBatch`.
        offsets: CSR offsets (unless ``values`` is a batch).
        k: Number of leading elements to keep per segment.
        method: One of ``METHODS``.
        bits_per_pass: Bits retired per radix pass.
        fill_value: Fill for rows of segments shorter than ``k``.
        tile_s: Tile side for the mask scans.
        block_tiles: Tiles per block for ``method="blocked"``.

    Returns:
        ``(topk_values, topk_indices, counts)`` — ``(S, k)`` values (filled
        past ``counts``), ``(S, k)`` int32 *segment-local* indices (-1 past
        ``counts``), and ``(S,)`` int32 ``counts = min(length, k)``.

    Example:
        >>> import jax.numpy as jnp
        >>> v, i, c = segment_topk(jnp.asarray([3, 1, 9, 2, 5], jnp.int32),
        ...                        jnp.asarray([0, 2, 5]), k=2)
        >>> v.tolist(), i.tolist(), c.tolist()
        ([[3, 1], [9, 5]], [[0, 1], [0, 2]], [2, 2])
    """
    values, offsets = _unwrap(values, offsets)
    n = values.shape[-1]
    num_segments = offsets.shape[0] - 1
    if n == 0:  # all segments empty: nothing to rank
        return (jnp.full((num_segments, k), fill_value, values.dtype),
                jnp.full((num_segments, k), -1, jnp.int32),
                jnp.zeros((num_segments,), jnp.int32))
    sv, sperm = segment_sort(values, offsets, descending=True, method=method,
                             bits_per_pass=bits_per_pass, tile_s=tile_s,
                             block_tiles=block_tiles)
    lens = offsets[1:] - offsets[:-1]
    counts = jnp.minimum(lens, k).astype(jnp.int32)
    col = jnp.arange(k, dtype=jnp.int32)[None, :]
    valid = col < counts[:, None]
    src = jnp.clip(offsets[:-1, None] + col, 0, max(n - 1, 0))
    vals = jnp.where(valid, jnp.take(sv, src), jnp.asarray(fill_value, sv.dtype))
    idx = jnp.where(valid, jnp.take(sperm, src) - offsets[:-1, None], -1)
    return vals, idx.astype(jnp.int32), counts


# ---------------------------------------------------------------------------
# segment_softmax / segment_top_p_sample — the ragged decode sampler
# ---------------------------------------------------------------------------


def segment_softmax(values, offsets=None, *, method: str = "auto",
                    tile_s: int = 128, block_tiles: int = 8) -> jax.Array:
    """Per-segment softmax of packed logits, in fp32.

    Max-subtraction uses an exact (order-independent) per-segment max; the
    normalizer is the per-segment total of the exponentials, read off the
    segmented scan.

    Args:
        values: Packed logits ``(n,)`` or a :class:`SegmentedBatch`.
        offsets: CSR offsets (unless ``values`` is a batch).
        method: Scan method for the normalizer, one of ``METHODS``.
        tile_s: Tile side for the matmul scans.
        block_tiles: Tiles per block for ``method="blocked"``.

    Returns:
        ``(n,)`` fp32 probabilities summing to 1 within each segment.

    Example:
        >>> import jax.numpy as jnp
        >>> p = segment_softmax(jnp.zeros(4), jnp.asarray([0, 1, 4]))
        >>> [round(float(v), 4) for v in p]
        [1.0, 0.3333, 0.3333, 0.3333]
    """
    values, offsets = _unwrap(values, offsets)
    n = values.shape[-1]
    num_segments = offsets.shape[0] - 1
    x = values.astype(jnp.float32)
    ids = segment_ids(offsets, n)
    m = jax.ops.segment_max(x, ids, num_segments=num_segments,
                            indices_are_sorted=True)
    e = jnp.exp(x - jnp.take(m, ids))
    denom = segment_sums(e, offsets, method=method, tile_s=tile_s,
                         block_tiles=block_tiles)
    return e / jnp.take(denom, ids)


def _segment_greedy(values, offsets, n: int, num_segments: int) -> jax.Array:
    """Per-segment argmax as a segment-local id — NaN as ``-inf``, ties low.

    The deterministic greedy fallback of dispatch rule 10: used for
    ``temperature == 0`` and for ``nonfinite="sanitize"`` on poisoned
    segments.  A segment whose entries are all ``-inf`` resolves to local
    id 0 (matching the batched sampler's convention).
    """
    x = jnp.asarray(values).astype(jnp.float32)
    x = jnp.where(jnp.isnan(x), -jnp.inf, x)
    ids = segment_ids(offsets, n)
    m = jax.ops.segment_max(x, ids, num_segments=num_segments,
                            indices_are_sorted=True)
    cand = jnp.where(x == jnp.take(m, ids), jnp.arange(n, dtype=jnp.int32),
                     jnp.asarray(n, jnp.int32))
    first = jax.ops.segment_min(cand, ids, num_segments=num_segments,
                                indices_are_sorted=True)
    return jnp.clip(first - offsets[:-1], 0, None).astype(jnp.int32)


def _reject_poisoned_packed_logits(values, offsets, n: int,
                                   num_segments: int) -> None:
    """The packed ``nonfinite="raise"`` gate for :func:`segment_top_p_sample`.

    ``-inf`` entries are legitimate vocab masks; what is rejected is NaN,
    ``+inf``, and any non-empty segment with no finite entry (no valid
    sample exists).  Concrete payloads raise
    :class:`repro.core.guards.NonFiniteError` eagerly; traced payloads stage
    a checkified assertion (fires through :func:`repro.core.guards.checked`).
    """
    if guards.is_concrete(values) and guards.is_concrete(offsets):
        v = np.asarray(values, dtype=np.float32)
        off = np.asarray(offsets)
        bad = bool(np.isnan(v).any() or np.isposinf(v).any())
        if not bad:
            finite = np.isfinite(v)
            for i in range(off.shape[0] - 1):
                seg = finite[off[i]:off[i + 1]]
                if seg.size and not seg.any():
                    bad = True
                    break
        if bad:
            raise guards.NonFiniteError(
                "segment_top_p_sample: poisoned logits under "
                "nonfinite='raise' — NaN, +inf, or a segment with no finite "
                "entry (-inf vocab masks are allowed)")
    else:
        from jax.experimental import checkify
        x = jnp.asarray(values).astype(jnp.float32)
        ids = segment_ids(offsets, n)
        has_finite = jax.ops.segment_max(
            jnp.isfinite(x).astype(jnp.int32), ids,
            num_segments=num_segments, indices_are_sorted=True)
        lens = offsets[1:] - offsets[:-1]
        ok = (~jnp.any(jnp.isnan(x)) & ~jnp.any(jnp.isposinf(x))
              & jnp.all((has_finite > 0) | (lens == 0)))
        checkify.debug_check(
            ok, "segment_top_p_sample: poisoned logits under "
                "nonfinite='raise'")


def segment_top_p_sample(values, offsets=None, key=None, p: float = 0.9,
                         temperature: float = 1.0, *, method: str = "auto",
                         bits_per_pass: int = 4, is_probs: bool = False,
                         u: Optional[jax.Array] = None, tile_s: int = 128,
                         block_tiles: int = 8,
                         nonfinite: str = "propagate") -> jax.Array:
    """Nucleus-sample every segment of a packed ragged batch in one launch.

    The packed analogue of :func:`repro.core.primitives.top_p_sample`:
    per-segment softmax, a descending segmented radix sort on bf16 keys, the
    segmented prefix sum of sorted probabilities, the nucleus cutoff, and a
    per-segment inverse-transform sample — every scan-shaped step running on
    the segmented matmul scan, so a ragged decode batch (active rows of
    different lengths) samples without padding to a rectangle.

    Args:
        values: Packed logits ``(n,)`` or a :class:`SegmentedBatch`.
        offsets: CSR offsets (unless ``values`` is a batch).
        key: JAX PRNG key; draws one uniform per segment (shape
            ``(num_segments, 1)``), so a rectangular batch consumes exactly
            the uniforms the batched sampler would.  Tokens then agree with
            the batched sampler except where fp32 rounding flips a
            threshold comparison (a flat packed scan accumulates
            differently from per-row scans — the module's float contract).
        p: Nucleus mass threshold in ``(0, 1]``.
        temperature: Logit divisor applied before the softmax;
            ``temperature == 0`` is the deterministic greedy limit
            (per-segment argmax, ties to the lowest id — no uniform is
            consumed).
        method: One of ``METHODS`` for every scan-shaped step.
        bits_per_pass: Bits retired per radix pass of the key sort.
        is_probs: If true, ``values`` are already per-segment probabilities
            (softmax and temperature are skipped).
        u: Optional ``(num_segments, 1)`` uniforms overriding the ``key``
            draw (deterministic replay / parity testing).
        tile_s: Tile side for the mask scans.
        block_tiles: Tiles per block for ``method="blocked"``.
        nonfinite: Non-finite logits policy (dispatch rule 10) —
            ``"raise"`` rejects NaN / ``+inf`` / fully-masked segments
            (``-inf`` vocab masks stay legal); ``"sanitize"`` maps poisoned
            segments to the deterministic per-segment greedy fallback.

    Returns:
        ``(num_segments,)`` int32 sampled *segment-local* token ids (0 for
        empty segments).

    Raises:
        ValueError: If ``p`` is outside ``[0, 1]`` or ``temperature`` is
            negative / non-finite, or the offsets break the CSR contract.
        repro.core.guards.NonFiniteError: Under ``nonfinite="raise"`` with
            concrete poisoned logits.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> logits = jnp.asarray([0.0, 20.0, 0.0, 0.0, 20.0])
        >>> segment_top_p_sample(logits, jnp.asarray([0, 3, 5]),
        ...                      jax.random.PRNGKey(0), p=0.9).tolist()
        [1, 1]
    """
    values, offsets = _unwrap(values, offsets, op="segment_top_p_sample")
    guards.validate_probability(p, op="segment_top_p_sample")
    guards.validate_temperature(temperature, op="segment_top_p_sample")
    nonfinite = guards.resolve_nonfinite(nonfinite)
    n = values.shape[-1]
    num_segments = offsets.shape[0] - 1
    if n == 0:  # all segments empty: the documented 0-per-segment result
        return jnp.zeros((num_segments,), jnp.int32)
    if not is_probs and guards.is_concrete(temperature) \
            and float(temperature) == 0.0:
        seg_lens = offsets[1:] - offsets[:-1]
        greedy = _segment_greedy(values, offsets, n, num_segments)
        return jnp.where(seg_lens > 0, greedy, 0).astype(jnp.int32)
    method = maybe_resolve(method, "segment_top_p_sample", n, values.dtype)
    kw = dict(method=method, tile_s=tile_s, block_tiles=block_tiles)
    if nonfinite == "raise":
        _reject_poisoned_packed_logits(values, offsets, n, num_segments)
    if is_probs:
        probs = values.astype(jnp.float32)
    else:
        v = values if temperature == 1.0 else values / temperature
        probs = segment_softmax(v, offsets, **kw)
    keys16 = probs.astype(jnp.bfloat16)
    _, order = segment_sort(keys16, offsets, descending=True,
                            bits_per_pass=bits_per_pass, **kw)
    sorted_p = jnp.take(probs, order)
    cum = segment_scan(sorted_p, offsets, **kw)
    cut = (cum - sorted_p) > p                    # llama3's sample_top_p formula
    masked = jnp.where(cut, 0.0, sorted_p)
    cdf = segment_scan(masked, offsets, **kw)
    totals = _segment_ends(cdf, offsets)
    if u is None:
        u = jax.random.uniform(key, (num_segments, 1), dtype=cdf.dtype)
    theta = u[..., 0].astype(cdf.dtype) * totals
    ids = segment_ids(offsets, n)
    less = (cdf < jnp.take(theta, ids)).astype(jnp.int32)
    cnt = _segment_ends(segment_scan(less, offsets, **kw), offsets)
    lens = offsets[1:] - offsets[:-1]
    j = jnp.clip(cnt, 0, jnp.maximum(lens - 1, 0))
    pos = jnp.clip(offsets[:-1] + j, 0, max(n - 1, 0))
    tok = jnp.take(order, pos) - offsets[:-1]
    tok = jnp.where(lens > 0, tok, 0).astype(jnp.int32)
    if nonfinite == "sanitize":
        bad = jax.ops.segment_max(
            (~jnp.isfinite(probs)).astype(jnp.int32), ids,
            num_segments=num_segments, indices_are_sorted=True) > 0
        greedy = _segment_greedy(values, offsets, n, num_segments)
        tok = jnp.where(bad & (lens > 0), greedy, tok)
    return tok
