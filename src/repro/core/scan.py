"""Matmul-based parallel scan (prefix sum) — the paper's core contribution.

Implements, in pure JAX (lowering to the TPU MXU via ``jnp.dot``):

* ``ScanU``   (paper Alg. 1): one matmul ``A @ U_s`` computes ``s`` local scans of
  length ``s``; the row partials are then propagated.  On Ascend the propagation is a
  serial vector-core loop; on TPU we use a log-depth VPU cumsum over the ``s`` row sums
  (see DESIGN.md §2, "assumptions that changed").
* ``ScanUL1`` (paper Alg. 2 / Eq. 1): the full ``ℓ = s²`` tile scan as matmuls only::

      scan(z) = A @ U_s  +  L⁻_s @ A @ 1_s

  where ``A`` is the row-major ``s×s`` view of the tile, ``U_s`` the upper-triangular
  all-ones matrix (incl. diagonal) and ``L⁻_s`` the *strictly* lower-triangular
  all-ones matrix.
* A multi-level block scan (SSA structure, paper §2.1/§4.3) so arbitrary lengths run
  in linear work: tile-local scans (MXU) + a scan over the tile sums + broadcast add.

dtype rules follow the paper's cube unit: ``int8 -> int32`` accumulation (mask scans),
``bf16/f16 -> f32`` accumulation, everything else accumulates in its own dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "scan",
    "cumsum",
    "tile_scan_scanu",
    "tile_scan_scanul1",
    "upper_ones",
    "strictly_lower_ones",
    "accum_dtype_for",
]

# ---------------------------------------------------------------------------
# Constant matrices (paper notation: U_s, L_s, L⁻_s, 1_s)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _np_upper_ones(s: int) -> np.ndarray:
    return np.triu(np.ones((s, s), dtype=np.float32))


@functools.lru_cache(maxsize=None)
def _np_strictly_lower_ones(s: int) -> np.ndarray:
    return np.tril(np.ones((s, s), dtype=np.float32), k=-1)


def upper_ones(s: int, dtype=jnp.float32) -> jax.Array:
    """U_s — upper triangular all-ones (including the main diagonal)."""
    return jnp.asarray(_np_upper_ones(s), dtype=dtype)


def strictly_lower_ones(s: int, dtype=jnp.float32) -> jax.Array:
    """L⁻_s — strictly lower triangular all-ones (zero diagonal)."""
    return jnp.asarray(_np_strictly_lower_ones(s), dtype=dtype)


def accum_dtype_for(dtype) -> jnp.dtype:
    """Accumulation dtype mirroring the Ascend cube unit I/O types.

    int8 inputs accumulate in int32 (the paper's mask-scan specialization);
    sub-fp32 floats accumulate in fp32 (cube f16 -> f32).
    """
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.dtype(jnp.int8), jnp.dtype(jnp.uint8), jnp.dtype(jnp.int16),
                 jnp.dtype(jnp.bool_)):
        return jnp.dtype(jnp.int32)
    if dtype in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)):
        return jnp.dtype(jnp.float32)
    return dtype


# ---------------------------------------------------------------------------
# Tile-local scans (one ℓ = s² tile viewed as an s×s row-major matrix A)
# ---------------------------------------------------------------------------


def tile_scan_scanu(a: jax.Array, *, accum_dtype=None) -> jax.Array:
    """ScanU tile step: ``A @ U_s`` + propagation of row partials.

    ``a``: (..., s, s) row-major tiles.  Returns the *full* tile scan (the matmul
    computes the s per-row local scans; propagation adds the exclusive cumsum of the
    row sums — on TPU a log-depth VPU op rather than Ascend's serial vector loop).
    """
    s = a.shape[-1]
    acc = accum_dtype or accum_dtype_for(a.dtype)
    u = upper_ones(s, _operand_dtype(a.dtype))
    local = jnp.matmul(a, u, preferred_element_type=acc).astype(acc)
    row_sums = local[..., :, -1]
    row_prefix = jnp.cumsum(row_sums, axis=-1, dtype=acc) - row_sums  # exclusive
    return local + row_prefix[..., :, None]


def tile_scan_scanul1(a: jax.Array, *, accum_dtype=None) -> jax.Array:
    """ScanUL1 tile step (paper Eq. 1): ``A@U + L⁻ @ (A@1)`` — matmuls only.

    ``A @ 1_s`` is computed as a row-sum broadcast (identical result, avoids one
    explicit matmul operand load); the ``L⁻`` product runs on the MXU and plays the
    role of the cube accumulation-buffer step (Alg. 2 line 12).
    """
    s = a.shape[-1]
    acc = accum_dtype or accum_dtype_for(a.dtype)
    od = _operand_dtype(a.dtype)
    u = upper_ones(s, od)
    lm = strictly_lower_ones(s, od)
    c2 = jnp.matmul(a, u, preferred_element_type=acc).astype(acc)
    # C1 = A @ 1_s  ==  row sums broadcast along columns.
    c1 = jnp.sum(a.astype(acc), axis=-1, keepdims=True) * jnp.ones((1, s), acc)
    c2 = c2 + jnp.matmul(lm.astype(acc), c1, preferred_element_type=acc)
    return c2


def _operand_dtype(dtype) -> jnp.dtype:
    """dtype in which the constant matrices / matmul operands are fed to the MXU."""
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.dtype(jnp.int8), jnp.dtype(jnp.bool_), jnp.dtype(jnp.uint8)):
        return jnp.dtype(jnp.int8)
    if dtype in (jnp.dtype(jnp.int16), jnp.dtype(jnp.int32)):
        return dtype
    if dtype == jnp.dtype(jnp.bfloat16):
        return dtype
    if dtype == jnp.dtype(jnp.float16):
        return dtype
    return jnp.dtype(jnp.float32)


_TILE_FNS = {"scanu": tile_scan_scanu, "scanul1": tile_scan_scanul1}


# ---------------------------------------------------------------------------
# Full scan over the last axis
# ---------------------------------------------------------------------------


def _scan_last_axis_matmul(x: jax.Array, s: int, variant: str, acc) -> jax.Array:
    """Multi-level SSA block scan over the last axis using matmul tile scans."""
    *lead, n = x.shape
    ell = s * s
    if n <= s:
        # Single row: one triangular matvec on the MXU.
        u = upper_ones(n, _operand_dtype(x.dtype)) if n > 1 else None
        if n == 1:
            return x.astype(acc)
        return jnp.matmul(x[..., None, :].astype(_operand_dtype(x.dtype)), u,
                          preferred_element_type=acc)[..., 0, :].astype(acc)

    n_pad = (-n) % ell
    xp = jnp.pad(x, [(0, 0)] * len(lead) + [(0, n_pad)]) if n_pad else x
    nt = xp.shape[-1] // ell
    tiles = xp.reshape(*lead, nt, s, s)
    local = _TILE_FNS[variant](tiles, accum_dtype=acc)          # (..., nt, s, s)
    tile_sums = local[..., -1, -1]                              # (..., nt)
    # Scan over the (much smaller) tile sums; recurse with the matmul method when the
    # tile-sum array itself is long enough to benefit.
    if nt > ell:
        tile_prefix = _scan_last_axis_matmul(tile_sums, s, variant, acc)
    else:
        tile_prefix = jnp.cumsum(tile_sums, axis=-1, dtype=acc)
    tile_prefix = tile_prefix - tile_sums                       # exclusive
    out = local + tile_prefix[..., None, None]
    out = out.reshape(*lead, nt * ell)
    return out[..., :n] if n_pad else out


def scan(
    x: jax.Array,
    axis: int = -1,
    *,
    exclusive: bool = False,
    reverse: bool = False,
    method: str = "matmul",
    variant: str = "scanul1",
    tile_s: int = 128,
    accum_dtype: Optional[jnp.dtype] = None,
) -> jax.Array:
    """Inclusive (or exclusive) prefix sum along ``axis``.

    method:
      * ``"matmul"`` — the paper's cube-unit algorithms (ScanU / ScanUL1 per
        ``variant``) with SSA multi-level blocking.  This is the default and the
        framework-wide cumsum used by MoE dispatch, sampling and the SSM layers.
      * ``"vector"`` — plain ``jnp.cumsum`` (the paper's vector-only baseline).
      * ``"kernel"`` — the fused Pallas TPU kernel (see ``repro.kernels``).
    """
    if method not in ("matmul", "vector", "kernel"):
        raise ValueError(f"unknown scan method {method!r}")
    if variant not in _TILE_FNS:
        raise ValueError(f"unknown scan variant {variant!r}")
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else accum_dtype_for(x.dtype)

    axis = axis % x.ndim
    if axis != x.ndim - 1:
        x = jnp.moveaxis(x, axis, -1)
    if reverse:
        x = jnp.flip(x, axis=-1)

    if method == "vector":
        out = jnp.cumsum(x, axis=-1, dtype=acc)
    elif method == "kernel":
        from repro.kernels import ops as _kops  # local import to avoid cycle
        out = _kops.scan_kernel(x, s=tile_s, variant=variant, accum_dtype=acc)
    else:
        out = _scan_last_axis_matmul(x, tile_s, variant, acc)

    if exclusive:
        pad = [(0, 0)] * (out.ndim - 1) + [(1, 0)]
        out = jnp.pad(out, pad)[..., :-1]
    if reverse:
        out = jnp.flip(out, axis=-1)
    if axis != x.ndim - 1:
        out = jnp.moveaxis(out, -1, axis)
    return out


def cumsum(x: jax.Array, axis: int = -1, **kw) -> jax.Array:
    """Drop-in ``jnp.cumsum`` replacement backed by the matmul scan."""
    return scan(x, axis=axis, **kw)
