"""Matmul-based parallel scan (prefix sum) — the paper's core contribution.

Implements, in pure JAX (lowering to the TPU MXU via ``jnp.dot``):

* ``ScanU``   (paper Alg. 1): one matmul ``A @ U_s`` computes ``s`` local scans of
  length ``s``; the row partials are then propagated.  On Ascend the propagation is a
  serial vector-core loop; on TPU we use a log-depth VPU cumsum over the ``s`` row sums
  (see DESIGN.md §2, "assumptions that changed").
* ``ScanUL1`` (paper Alg. 2 / Eq. 1): the full ``ℓ = s²`` tile scan as matmuls only::

      scan(z) = A @ U_s  +  L⁻_s @ A @ 1_s

  where ``A`` is the row-major ``s×s`` view of the tile, ``U_s`` the upper-triangular
  all-ones matrix (incl. diagonal) and ``L⁻_s`` the *strictly* lower-triangular
  all-ones matrix.
* A multi-level block scan (SSA structure, paper §2.1/§4.3) so arbitrary lengths run
  in linear work: tile-local scans (MXU) + a scan over the tile sums + broadcast add.

Dtype rules follow the paper's cube unit: ``int8 -> int32`` accumulation (mask scans),
``bf16/f16 -> f32`` accumulation, everything else accumulates in its own dtype.  See
:func:`accum_dtype_for`; the full paper-section-to-module map lives in
``docs/paper_map.md``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guards
from repro.core.autotune import maybe_resolve
from repro.core.precision import pdot, resolve_precision

__all__ = [
    "scan",
    "cumsum",
    "tile_scan_scanu",
    "tile_scan_scanul1",
    "upper_ones",
    "strictly_lower_ones",
    "accum_dtype_for",
]

METHODS = ("matmul", "vector", "kernel", "blocked")

# ---------------------------------------------------------------------------
# Constant matrices (paper notation: U_s, L_s, L⁻_s, 1_s)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _np_upper_ones(s: int) -> np.ndarray:
    return np.triu(np.ones((s, s), dtype=np.float32))


@functools.lru_cache(maxsize=None)
def _np_strictly_lower_ones(s: int) -> np.ndarray:
    return np.tril(np.ones((s, s), dtype=np.float32), k=-1)


def upper_ones(s: int, dtype=jnp.float32) -> jax.Array:
    """Build ``U_s`` — upper triangular all-ones (including the main diagonal).

    ``z @ U_s`` is the length-``s`` inclusive scan of a row vector ``z``; this
    is the constant operand of the paper's cube-unit matmuls (Alg. 1 line 5).

    Args:
        s: Matrix order (the paper's tile side; 128 matches the MXU).
        dtype: Element dtype the matrix is materialized in.

    Returns:
        An ``(s, s)`` array of the requested dtype.

    Example:
        >>> import jax.numpy as jnp
        >>> upper_ones(3, jnp.int32).tolist()
        [[1, 1, 1], [0, 1, 1], [0, 0, 1]]
    """
    return jnp.asarray(_np_upper_ones(s), dtype=dtype)


def strictly_lower_ones(s: int, dtype=jnp.float32) -> jax.Array:
    """Build ``L⁻_s`` — strictly lower triangular all-ones (zero diagonal).

    ``L⁻_s @ v`` is the *exclusive* prefix of ``v``; it propagates row/tile
    partials entirely on the matrix engine (paper Eq. 1, Alg. 2 line 12).

    Args:
        s: Matrix order.
        dtype: Element dtype the matrix is materialized in.

    Returns:
        An ``(s, s)`` array of the requested dtype.

    Example:
        >>> import jax.numpy as jnp
        >>> strictly_lower_ones(3, jnp.int32).tolist()
        [[0, 0, 0], [1, 0, 0], [1, 1, 0]]
    """
    return jnp.asarray(_np_strictly_lower_ones(s), dtype=dtype)


def accum_dtype_for(dtype) -> jnp.dtype:
    """Accumulation dtype mirroring the Ascend cube unit I/O types.

    The cube unit widens narrow inputs while accumulating: int8/uint8/int16 and
    bool inputs accumulate in int32 (the paper's mask-scan specialization used
    by ``split``/``compress``), and sub-fp32 floats (bf16/f16) accumulate in
    fp32.  Everything else accumulates in its own dtype.  Every ``scan``
    method — including the ``jnp.cumsum`` vector baseline — returns this
    dtype, which is what makes the methods bit-comparable.

    Args:
        dtype: Input element dtype (anything ``jnp.dtype`` accepts).

    Returns:
        The ``jnp.dtype`` scans over this input accumulate and return in.

    Example:
        >>> import jax.numpy as jnp
        >>> str(accum_dtype_for(jnp.int8)), str(accum_dtype_for(jnp.bfloat16))
        ('int32', 'float32')
        >>> str(accum_dtype_for(jnp.float32))
        'float32'
    """
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.dtype(jnp.int8), jnp.dtype(jnp.uint8), jnp.dtype(jnp.int16),
                 jnp.dtype(jnp.bool_)):
        return jnp.dtype(jnp.int32)
    if dtype in (jnp.dtype(jnp.bfloat16), jnp.dtype(jnp.float16)):
        return jnp.dtype(jnp.float32)
    return dtype


# ---------------------------------------------------------------------------
# Tile-local scans (one ℓ = s² tile viewed as an s×s row-major matrix A)
# ---------------------------------------------------------------------------


def tile_scan_scanu(a: jax.Array, *, accum_dtype=None,
                    precision: str = "highest") -> jax.Array:
    """ScanU tile step (paper Alg. 1): ``A @ U_s`` + propagation of row partials.

    The matmul computes the ``s`` per-row local scans; propagation then adds
    the exclusive cumsum of the row sums — on TPU a log-depth VPU op rather
    than Ascend's serial vector loop.

    Args:
        a: ``(..., s, s)`` row-major tile(s); a tile is the ``s×s`` matrix view
            of ``ℓ = s²`` consecutive sequence elements.
        accum_dtype: Accumulation dtype override; defaults to
            ``accum_dtype_for(a.dtype)``.
        precision: Engine feed precision for the fp32 contraction
            (:mod:`repro.core.precision`); only affects fp32 tiles.

    Returns:
        The full inclusive tile scan, shape ``(..., s, s)``, in the
        accumulation dtype.

    Example:
        >>> import jax.numpy as jnp
        >>> a = jnp.arange(1.0, 5.0).reshape(2, 2)   # the sequence 1,2,3,4
        >>> tile_scan_scanu(a).tolist()
        [[1.0, 3.0], [6.0, 10.0]]
    """
    s = a.shape[-1]
    acc = accum_dtype or accum_dtype_for(a.dtype)
    u = upper_ones(s, _operand_dtype(a.dtype))
    local = pdot(a, u, acc=acc, precision=precision, exact="right").astype(acc)
    row_sums = local[..., :, -1]
    row_prefix = jnp.cumsum(row_sums, axis=-1, dtype=acc) - row_sums  # exclusive
    return local + row_prefix[..., :, None]


def tile_scan_scanul1(a: jax.Array, *, accum_dtype=None,
                      precision: str = "highest") -> jax.Array:
    """ScanUL1 tile step (paper Alg. 2 / Eq. 1): ``A@U + L⁻ @ (A@1)`` — matmuls only.

    ``A @ 1_s`` is computed as a row-sum broadcast (identical result, avoids one
    explicit matmul operand load); the ``L⁻`` product runs on the MXU and plays the
    role of the cube accumulation-buffer step (Alg. 2 line 12).

    Args:
        a: ``(..., s, s)`` row-major tile(s).
        accum_dtype: Accumulation dtype override; defaults to
            ``accum_dtype_for(a.dtype)``.
        precision: Engine feed precision for the fp32 contractions
            (:mod:`repro.core.precision`); only affects fp32 tiles.

    Returns:
        The full inclusive tile scan, shape ``(..., s, s)``, in the
        accumulation dtype.

    Example:
        >>> import jax.numpy as jnp
        >>> a = jnp.arange(1.0, 5.0).reshape(2, 2)
        >>> tile_scan_scanul1(a).tolist()
        [[1.0, 3.0], [6.0, 10.0]]
    """
    s = a.shape[-1]
    acc = accum_dtype or accum_dtype_for(a.dtype)
    od = _operand_dtype(a.dtype)
    u = upper_ones(s, od)
    lm = strictly_lower_ones(s, od)
    c2 = pdot(a, u, acc=acc, precision=precision, exact="right").astype(acc)
    # C1 = A @ 1_s  ==  row sums broadcast along columns.
    c1 = jnp.sum(a.astype(acc), axis=-1, keepdims=True) * jnp.ones((1, s), acc)
    c2 = c2 + pdot(lm.astype(acc), c1, acc=acc, precision=precision, exact="left")
    return c2


def _operand_dtype(dtype) -> jnp.dtype:
    """Dtype in which the constant matrices / matmul operands are fed to the MXU."""
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.dtype(jnp.int8), jnp.dtype(jnp.bool_), jnp.dtype(jnp.uint8)):
        return jnp.dtype(jnp.int8)
    if dtype in (jnp.dtype(jnp.int16), jnp.dtype(jnp.int32)):
        return dtype
    if dtype == jnp.dtype(jnp.bfloat16):
        return dtype
    if dtype == jnp.dtype(jnp.float16):
        return dtype
    return jnp.dtype(jnp.float32)


_TILE_FNS = {"scanu": tile_scan_scanu, "scanul1": tile_scan_scanul1}


# ---------------------------------------------------------------------------
# Full scan over the last axis
# ---------------------------------------------------------------------------


def _scan_last_axis_matmul(x: jax.Array, s: int, variant: str, acc,
                           precision: str = "highest") -> jax.Array:
    """Multi-level SSA block scan over the last axis using matmul tile scans."""
    *lead, n = x.shape
    ell = s * s
    if n <= s:
        # Single row: one triangular matvec on the MXU.
        u = upper_ones(n, _operand_dtype(x.dtype)) if n > 1 else None
        if n == 1:
            return x.astype(acc)
        return pdot(x[..., None, :].astype(_operand_dtype(x.dtype)), u,
                    acc=acc, precision=precision,
                    exact="right")[..., 0, :].astype(acc)

    n_pad = (-n) % ell
    xp = jnp.pad(x, [(0, 0)] * len(lead) + [(0, n_pad)]) if n_pad else x
    nt = xp.shape[-1] // ell
    tiles = xp.reshape(*lead, nt, s, s)
    local = _TILE_FNS[variant](tiles, accum_dtype=acc,
                               precision=precision)             # (..., nt, s, s)
    tile_sums = local[..., -1, -1]                              # (..., nt)
    # Scan over the (much smaller) tile sums; recurse with the matmul method when the
    # tile-sum array itself is long enough to benefit.
    if nt > ell:
        tile_prefix = _scan_last_axis_matmul(tile_sums, s, variant, acc, precision)
    else:
        tile_prefix = jnp.cumsum(tile_sums, axis=-1, dtype=acc)
    tile_prefix = tile_prefix - tile_sums                       # exclusive
    out = local + tile_prefix[..., None, None]
    out = out.reshape(*lead, nt * ell)
    return out[..., :n] if n_pad else out


def scan(
    x: jax.Array,
    axis: int = -1,
    *,
    exclusive: bool = False,
    reverse: bool = False,
    method: str = "auto",
    precision: str = "highest",
    variant: str = "scanul1",
    tile_s: int = 128,
    block_tiles: int = 8,
    accum_dtype: Optional[jnp.dtype] = None,
    nonfinite: str = "propagate",
) -> jax.Array:
    """Inclusive (or exclusive) prefix sum along ``axis``.

    This is the framework-wide cumsum: the §5 operators (``split``, ``sort``,
    ``top_p_sample``, …), MoE dispatch and the SSM layers all route through it.
    The output dtype is always the accumulation dtype (``int8 -> int32``,
    ``bf16/f16 -> f32``; see :func:`accum_dtype_for`) regardless of method,
    which makes methods directly comparable.

    Args:
        x: Input array, any shape and any dtype :func:`accum_dtype_for` knows.
        axis: Axis to scan along (scans always execute over the last axis; other
            axes are moved there and back).
        exclusive: If true, shift the result right by one with a leading zero.
        reverse: If true, scan from the end (suffix sums).
        method: Execution strategy — ``"auto"`` (the default) resolves to one
            of ``METHODS`` per (op, length, dtype, backend) from the committed
            tuning table (:mod:`repro.core.autotune`; resolution is static, so
            the traced jaxpr is identical to passing the resolved method), or
            one of ``METHODS`` explicitly:

            * ``"matmul"`` — the paper's cube-unit algorithms (ScanU / ScanUL1
              per ``variant``) as XLA matmuls with SSA multi-level blocking.
            * ``"vector"`` — plain ``jnp.cumsum`` (the paper's vector-only
              baseline).
            * ``"kernel"`` — the fused sequential-grid Pallas kernel
              (``repro.kernels.scan_mm``): one launch, tiles walked in order
              with an SMEM-carried running partial.
            * ``"blocked"`` — the three-phase multi-core pipeline of paper §4
              (``repro.kernels.scan_pipeline``): parallel per-block partial
              scans, a block-sum carry scan, and a fused carry broadcast-add,
              so each element is read and written once.
        precision: Engine feed precision for the matmul methods
            (``"highest"``/``"compensated"``/``"fast"``), resolved pre-trace
            like ``method`` (:mod:`repro.core.precision`; ``precision_override``
            context > ``REPRO_SCAN_PRECISION`` env > this argument — dispatch
            rule 9).  ``"compensated"`` contracts fp32 inputs on the fp16
            engine via exact Ozaki high/low splits and matches
            ``method="vector"`` within the documented ulp bound; ``"fast"``
            feeds the bf16 engine (loose bound).  Only fp32 inputs are
            affected; integer scans stay exact.  Explicitly combining a
            non-default precision with ``method="vector"`` raises.
        variant: Tile algebra, ``"scanu"`` (Alg. 1, VPU row propagation) or
            ``"scanul1"`` (Alg. 2 / Eq. 1, propagation as an ``L⁻`` matmul).
        tile_s: Tile side ``s`` (a tile covers ``s²`` elements; 128 = MXU size).
        block_tiles: Tiles per block for ``method="blocked"`` (ignored
            otherwise); a block covers ``block_tiles * tile_s²`` elements.
        accum_dtype: Accumulation dtype override; defaults to
            ``accum_dtype_for(x.dtype)``.
        nonfinite: Non-finite input policy (:mod:`repro.core.guards`,
            dispatch rule 10), resolved pre-trace like ``method`` and
            ``precision`` (``nonfinite_override`` context > ``REPRO_NONFINITE``
            env > this argument).  ``"propagate"`` (default) keeps IEEE
            semantics and adds zero ops; ``"raise"`` rejects non-finite
            inputs (eagerly when concrete, as a checkified assertion under
            trace); ``"sanitize"`` replaces non-finite elements with 0 (the
            additive identity).  Integer scans are unaffected.

    Returns:
        The scanned array, same shape as ``x``, in the accumulation dtype.

    Raises:
        ValueError: If ``method``, ``precision``, ``variant`` or ``nonfinite``
            is unknown, ``axis`` is out of bounds, or an explicit non-default
            ``precision`` is combined with an explicit ``method="vector"``.

    Example:
        >>> import jax.numpy as jnp
        >>> [int(v) for v in scan(jnp.arange(1, 9, dtype=jnp.int32))]
        [1, 3, 6, 10, 15, 21, 28, 36]
        >>> out = scan(jnp.ones(10, jnp.int8), method="blocked", tile_s=8)
        >>> out.dtype.name, int(out[-1])
        ('int32', 10)
        >>> [int(v) for v in scan(jnp.arange(1, 5, dtype=jnp.int32), exclusive=True)]
        [0, 1, 3, 6]
    """
    if method != "auto" and method not in METHODS:
        raise ValueError(f"unknown scan method {method!r}; expected one of "
                         f"{METHODS + ('auto',)}")
    if variant not in _TILE_FNS:
        raise ValueError(f"unknown scan variant {variant!r}")
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else accum_dtype_for(x.dtype)

    axis = guards.validate_axis(axis, x.ndim, op="scan")
    explicit_method = method != "auto"
    method = maybe_resolve(method, "scan", x.shape[axis], x.dtype)
    precision = resolve_precision(precision, method=method,
                                  explicit_method=explicit_method)
    x = guards.apply_nonfinite(x, guards.resolve_nonfinite(nonfinite),
                               op="scan")
    if axis != x.ndim - 1:
        x = jnp.moveaxis(x, axis, -1)
    if reverse:
        x = jnp.flip(x, axis=-1)

    if method == "vector":
        out = jnp.cumsum(x, axis=-1, dtype=acc)
    elif method == "kernel":
        from repro.kernels import ops as _kops  # local import to avoid cycle
        out = _kops.scan_kernel(x, s=tile_s, variant=variant, accum_dtype=acc,
                                precision=precision)
    elif method == "blocked":
        from repro.kernels import ops as _kops  # local import to avoid cycle
        out = _kops.blocked_scan_kernel(x, s=tile_s, block_tiles=block_tiles,
                                        variant=variant, accum_dtype=acc,
                                        precision=precision)
    else:
        out = _scan_last_axis_matmul(x, tile_s, variant, acc, precision)

    if exclusive:
        pad = [(0, 0)] * (out.ndim - 1) + [(1, 0)]
        out = jnp.pad(out, pad)[..., :-1]
    if reverse:
        out = jnp.flip(out, axis=-1)
    if axis != x.ndim - 1:
        out = jnp.moveaxis(out, -1, axis)
    return out


def cumsum(x: jax.Array, axis: int = -1, **kw) -> jax.Array:
    """Drop-in ``jnp.cumsum`` replacement backed by the matmul scan.

    Args:
        x: Input array.
        axis: Axis to scan along.
        **kw: Forwarded to :func:`scan` (``method=``, ``variant=``, …).

    Returns:
        ``scan(x, axis=axis, **kw)`` — inclusive prefix sums in the
        accumulation dtype.

    Example:
        >>> import jax.numpy as jnp
        >>> [int(v) for v in cumsum(jnp.asarray([1, 1, 2], jnp.int32))]
        [1, 2, 4]
    """
    return scan(x, axis=axis, **kw)
