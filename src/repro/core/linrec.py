"""Linear-recurrence scan (``y_t = a_t * y_{t-1} + b_t``) on the matmul tile machinery.

The paper's ScanU/ScanUL1 tile scans are the ``a ≡ 1`` special case of a more
general fact: any first-order linear recurrence is an associative scan that a
matrix engine can batch.  Where the prefix sum contracts a tile against the
*all-ones* upper-triangular ``U_s``, the linear recurrence contracts against
the **weighted** triangular matrix

    W[i, j] = Π_{k = j+1 .. i} a_k          (i >= j; 1 on the diagonal)

so one ``W @ b`` MXU contraction yields a whole tile row's recurrence — the
TCU scan formulation of Dakkak et al. and the SIMD² generalized-semiring view
(see PAPERS.md).  ``W`` is built in-register from cumulative products (the
log/product trick of :mod:`repro.core.ssd`): with ``p = cumprod(a')`` (zeros
replaced by 1), ``W[i, j] = p_i / p_j`` wherever no true zero of ``a`` lies in
``(j, i]`` — exactly-representable quotients divide exactly, which is what
keeps integer-valued payloads bit-identical across methods.

:func:`linear_scan` dispatches through the same ``method=`` table as
:func:`repro.core.scan.scan`:

* ``"matmul"`` — chunked ``W @ b`` contractions with a recursive cross-chunk
  affine carry scan (the SSA multi-level blocking of the prefix scan).
* ``"vector"`` — ``jax.lax.associative_scan`` over affine pairs
  ``(a, b) ⊕ (a', b') = (a·a', a'·b + b')`` (the correctness oracle).
* ``"kernel"`` — the fused sequential-grid Pallas kernel
  (:mod:`repro.kernels.linrec_mm`): tile scans with the running state carried
  in SMEM (the affine ``(Π a, sum)`` pair degenerates on a sequential walk).
* ``"blocked"`` — the §4 three-phase pipeline where phase 2 scans per-block
  ``(Π a, trailing affine sum)`` summaries, so multi-block inputs still read
  and write each element once.

Accumulation dtype (:func:`linrec_accum_dtype_for`): floats follow
``accum_dtype_for`` (bf16/f16 -> f32); integer and bool inputs accumulate in
**fp32** — the weighted-triangular construction divides cumulative products,
which needs a field, and exactness for integer-valued payloads is preserved
because exact quotients divide exactly.  This is the one documented deviation
from the prefix-scan dtype rule (int8 -> int32 there).

Numerical contract (enforced by ``tests/test_linrec.py``): every method is
bit-identical to ``"vector"`` for integer-valued payloads whose partial
products/sums stay exactly representable, and within tight ulp tolerance for
fp32/bf16 gated recurrences (``a = exp(a_log) ∈ (0, 1]``).  The in-register
products are exponent-normalized (see :func:`_pair_w`), so windowed products
never under- or overflow *internally* — ``W`` entries saturate to 0/inf only
when the true window product leaves the dtype's range, matching the vector
path's behaviour on the same inputs (no NaNs from ``0/0``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import guards
from repro.core.autotune import maybe_resolve
from repro.core.precision import normalize_exponents, pdot, resolve_precision
from repro.core.primitives import _register, dispatch
from repro.core.scan import METHODS, accum_dtype_for

__all__ = [
    "linear_scan", "cumprod", "cummax", "linrec_accum_dtype_for",
]


def linrec_accum_dtype_for(dtype) -> jnp.dtype:
    """Accumulation dtype for linear-recurrence scans.

    Floats follow :func:`repro.core.scan.accum_dtype_for` (bf16/f16 -> f32);
    integer and bool inputs accumulate in fp32 because the weighted-triangular
    matmul formulation divides cumulative products (a field operation) —
    integer-*valued* payloads stay exact, see the module docstring.

    Args:
        dtype: Input element dtype.

    Returns:
        The ``jnp.dtype`` linear scans over this input accumulate and return
        in.

    Example:
        >>> import jax.numpy as jnp
        >>> str(linrec_accum_dtype_for(jnp.int8)), str(linrec_accum_dtype_for(jnp.bfloat16))
        ('float32', 'float32')
        >>> str(linrec_accum_dtype_for(jnp.float32))
        'float32'
    """
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.integer) or dtype == jnp.dtype(jnp.bool_):
        return jnp.dtype(jnp.float32)
    return accum_dtype_for(dtype)


# ---------------------------------------------------------------------------
# In-register weighted-triangular algebra (shared with repro.kernels.linrec_mm)
# ---------------------------------------------------------------------------


# Longest axis _pair_w accepts: normalized mantissas lie in [√½, √2), so a
# cumulative product over n of them stays within 2^±(n/2) — safely inside
# fp32's exponent range for n ≤ 256.  Longer chains must be chunked through
# the recursive carry scan (as _linrec_matmul and _linrec_block do).
MAX_TILE = 256


def _pair_w(a: jax.Array, acc) -> jax.Array:
    """Weighted triangular operand ``W[..., i, j] = Π_{k=j+1..i} a_k``.

    The linear-recurrence analogue of the paper's ``U_s`` (which is the
    ``a ≡ 1`` case, transposed): ``(W @ b)[i]`` is the inclusive recurrence of
    row ``b`` under multipliers ``a``, so one batched MXU contraction scans a
    whole tile.  Built in-register from cumulative products of
    **exponent-normalized** multipliers: each ``a_k`` splits exactly into
    ``a_norm_k · 2^{e_k}`` with ``|a_norm_k| ∈ [√½, √2)``
    (:func:`repro.core.precision.normalize_exponents` — the same exact
    power-of-two machinery the compensated fp16 split scales its slices
    with; no rounding), the mantissa product/quotient
    never under- or overflows for tile-bounded windows, and the integer
    exponents travel through an exact ``cumsum``, re-applied per window with
    ``ldexp`` (which saturates gracefully to 0/inf only when the *true*
    window product does).  Zeros of ``a`` are replaced by 1 for the running
    product and re-imposed by masking every window that straddles one (a
    ``cummax`` of the last-zero position, exactly like the boundary masks of
    ``segscan_mm``).  Integer-valued payloads stay bit-exact: normalization
    only moves exponents, so quotients of exactly-representable products
    still divide exactly.
    """
    s = a.shape[-1]
    az = a == 0
    a1 = jnp.where(az, jnp.ones((), acc), a.astype(acc))
    a_norm, e = normalize_exponents(a1, acc)            # |a_norm| ∈ [√½, √2)
    es = jnp.cumsum(e, axis=-1)
    p = jnp.cumprod(a_norm, axis=-1)                    # |p| ∈ 2^±(s/2): safe
    pos = jax.lax.broadcasted_iota(jnp.int32, a.shape, a.ndim - 1)
    lastz = jax.lax.cummax(jnp.where(az, pos, -1), axis=a.ndim - 1)
    ri = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    cj = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    keep = (ri > cj) & (lastz[..., :, None] <= cj)
    ratio = p[..., :, None] / p[..., None, :]
    w = jnp.ldexp(ratio, es[..., :, None] - es[..., None, :])
    w = jnp.where(keep, w, jnp.zeros((), acc))
    return jnp.where(ri == cj, jnp.ones((), acc), w)


def _w_matvec(w: jax.Array, b: jax.Array, acc,
              precision: str = "highest") -> jax.Array:
    """Batched ``(..., s, s) @ (..., s)`` contraction in the accumulation dtype.

    The one data×data contraction of the subsystem: under
    ``precision="compensated"`` *both* operands Ozaki-split (``W`` per row,
    ``b`` per vector — 3 fp16 products, the ``lo×lo`` term dropped).
    """
    return pdot(w, b.astype(acc)[..., None], acc=acc, precision=precision,
                exact="none")[..., 0].astype(acc)


def _linrec_block(a2: jax.Array, b2: jax.Array, acc, precision: str = "highest"):
    """Linear recurrence of one ``(m, s)`` row-major block held in VMEM/registers.

    The ScanUL1 structure generalized to weighted triangles: per-row ``W @ b``
    contractions give the ``m`` row-local recurrences; rows are then chained
    through their affine summaries ``(row product, row-local last value)`` by
    a second weighted-triangular contraction over the ``m`` row products (the
    ``L⁻`` role of paper Eq. 1).  Returns ``(out, mult)`` where ``out`` is the
    block-local recurrence (zero incoming state) and ``mult[r, i] =
    Π a[block start .. (r, i)]`` is the multiplier an incoming carry picks up
    — plain cumulative products, zeros included exactly.
    """
    rowmult = jnp.cumprod(a2.astype(acc), axis=-1)       # (m, s)
    local = _w_matvec(_pair_w(a2, acc), b2, acc, precision)  # (m, s) row-local
    rp = rowmult[..., :, -1]                             # row products
    rl = local[..., :, -1]                               # row-local last values
    if rp.shape[-1] <= MAX_TILE:
        y_rows = _w_matvec(_pair_w(rp, acc), rl, acc, precision)
    else:  # tall blocks: chain the row summaries through the chunked scan
        y_rows = _linrec_matmul(rp, rl, method="matmul", tile_s=128,
                                block_tiles=0, accum_dtype=acc,
                                precision=precision)
    pad_row = [(0, 0)] * (y_rows.ndim - 1) + [(1, 0)]
    carry_rows = jnp.pad(y_rows, pad_row)[..., :-1]      # exclusive
    out = local + rowmult * carry_rows[..., :, None]
    rowprefix = jnp.pad(jnp.cumprod(rp, axis=-1),
                        pad_row, constant_values=1)[..., :-1]
    mult = rowmult * rowprefix[..., :, None]
    return out, mult


# ---------------------------------------------------------------------------
# Method implementations (registered in the shared dispatch table)
# ---------------------------------------------------------------------------


@_register("linear_scan", "vector")
def _linrec_vector(a, b, *, method, tile_s, block_tiles, accum_dtype,
                   precision="highest"):
    """Affine-pair ``associative_scan`` — the correctness oracle."""
    acc = accum_dtype
    av = a.astype(acc)
    # the b leaf's shape must be stable across combines -> broadcast it up
    # front; the (smaller) a leaf only ever combines with itself.
    bv = jnp.broadcast_to(b.astype(acc), jnp.broadcast_shapes(a.shape, b.shape))

    def comb(left, right):
        """Compose affine maps: (right ∘ left)(y) = a_r(a_l y + b_l) + b_r."""
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    _, out = jax.lax.associative_scan(comb, (av, bv), axis=-1)
    return out


@_register("linear_scan", "matmul")
def _linrec_matmul(a, b, *, method, tile_s, block_tiles, accum_dtype,
                   precision="highest"):
    """Chunked ``W @ b`` contractions + recursive cross-chunk affine carry scan.

    Chunks of ``tile_s`` elements each contract against their in-register
    ``W``; the per-chunk summaries ``(Π a, local last value)`` are themselves
    a linear recurrence one level up (the SSA blocking of the prefix scan),
    scanned by recursing until a single chunk remains.

    ``a`` and ``b`` may have broadcast leading dims (rank-aligned by
    ``linear_scan``, equal scan-axis length): ``W`` is built from the
    *unbroadcast* multipliers, so a decay shared across payload dims — the
    SSD cross-chunk case — gets ONE weighted triangle contracted against the
    whole payload batch instead of one triangle per payload element.
    """
    acc = accum_dtype
    q = tile_s
    n = a.shape[-1]
    if n <= q:
        return _w_matvec(_pair_w(a, acc), b, acc, precision)
    pad = (-n) % q
    if pad:  # identity affine element: a = 1, b = 0
        a = jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)], constant_values=1)
        b = jnp.pad(b, [(0, 0)] * (b.ndim - 1) + [(0, pad)])
    nc = a.shape[-1] // q
    ac = a.reshape(*a.shape[:-1], nc, q)
    bc = b.reshape(*b.shape[:-1], nc, q)
    local = _w_matvec(_pair_w(ac, acc), bc, acc, precision)  # (..., nc, q)
    mult = jnp.cumprod(ac.astype(acc), axis=-1)          # carry multipliers
    pa = mult[..., -1]                                   # chunk products
    sb = local[..., -1]                                  # chunk local lasts
    carry_inc = _linrec_matmul(pa, sb, method=method, tile_s=q,
                               block_tiles=block_tiles, accum_dtype=acc,
                               precision=precision)
    pad_c = [(0, 0)] * (carry_inc.ndim - 1) + [(1, 0)]
    carry_in = jnp.pad(carry_inc, pad_c)[..., :-1]       # exclusive
    out = local + mult * carry_in[..., None]
    out = out.reshape(*out.shape[:-2], nc * q)
    return out[..., :n] if pad else out


def _broadcast_pair(a, b):
    """Materialize the common shape (the Pallas wrappers flatten to rows)."""
    shp = jnp.broadcast_shapes(a.shape, b.shape)
    return jnp.broadcast_to(a, shp), jnp.broadcast_to(b, shp)


@_register("linear_scan", "kernel")
def _linrec_kernel(a, b, *, method, tile_s, block_tiles, accum_dtype,
                   precision="highest"):
    """Fused sequential-grid tile kernel with the SMEM running-state carry."""
    from repro.kernels import ops as _kops  # local import to avoid cycle
    a, b = _broadcast_pair(a, b)
    return _kops.linrec_kernel(a, b, s=tile_s, accum_dtype=accum_dtype,
                               precision=precision)


@_register("linear_scan", "blocked")
def _linrec_blocked(a, b, *, method, tile_s, block_tiles, accum_dtype,
                    precision="highest"):
    """§4 three-phase pipeline with an affine phase-2 carry scan."""
    from repro.kernels import ops as _kops  # local import to avoid cycle
    a, b = _broadcast_pair(a, b)
    return _kops.linrec_blocked_kernel(a, b, s=tile_s, block_tiles=block_tiles,
                                       accum_dtype=accum_dtype,
                                       precision=precision)


# ---------------------------------------------------------------------------
# Dispatch core with the analytic adjoint
# ---------------------------------------------------------------------------
#
# The VJP of a linear recurrence is itself a linear recurrence, run in
# reverse:  with  y_t = a_t y_{t-1} + b_t  and output cotangent ȳ,
#
#     λ_t = ȳ_t + a_{t+1} λ_{t+1},      b̄_t = λ_t,      ā_t = λ_t · y_{t-1}.
#
# Differentiating through the W construction instead would square tiny
# cumulative products in the quotient rule (NaN/inf for strongly decaying
# gates), and the Pallas methods have no autodiff at all — the custom VJP
# gives every method the same robust analytic gradient, computed by the very
# same dispatcher (the backward pass is one more method-matched scan).


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def _linrec_core(a, b, method, tile_s, block_tiles, acc, precision):
    """Method-dispatched inclusive recurrence over the last axis (zero init)."""
    return dispatch("linear_scan", method)(
        a, b, method=method, tile_s=tile_s, block_tiles=block_tiles,
        accum_dtype=acc, precision=precision)


def _linrec_core_fwd(a, b, method, tile_s, block_tiles, acc, precision):
    """Forward pass; residuals are the multipliers and the output states."""
    y = _linrec_core(a, b, method, tile_s, block_tiles, acc, precision)
    return y, (a, y)


def _unbroadcast(x, shape):
    """Sum-reduce ``x`` back to a rank-aligned primal ``shape`` it broadcast from."""
    if x.shape == tuple(shape):
        return x
    axes = tuple(i for i, (xs, ps) in enumerate(zip(x.shape, shape))
                 if ps == 1 and xs != 1)
    return jnp.sum(x, axis=axes, keepdims=True)


def _linrec_core_bwd(method, tile_s, block_tiles, acc, precision, res, g):
    """Reverse-recurrence adjoint (module comment above), method-matched.

    ``b`` enters the core pre-broadcast to the output shape (public wrapper),
    so its cotangent is ``lam`` as-is; ``a`` may carry broadcast leading dims
    (shared decays) whose cotangent sum-reduces back to the primal shape.
    The backward recurrence reruns the dispatcher with the same ``precision``
    — a compensated forward pass gets a compensated adjoint.
    """
    a, y = res
    ash = jnp.concatenate([a[..., 1:], jnp.ones_like(a[..., :1])], axis=-1)
    lam = jnp.flip(
        _linrec_core(jnp.flip(ash, axis=-1), jnp.flip(g.astype(acc), axis=-1),
                     method, tile_s, block_tiles, acc, precision), axis=-1)
    y_prev = jnp.concatenate([jnp.zeros_like(y[..., :1]), y[..., :-1]], axis=-1)
    ga = _unbroadcast(lam * y_prev, a.shape).astype(a.dtype)
    return ga, lam.astype(acc)


_linrec_core.defvjp(_linrec_core_fwd, _linrec_core_bwd)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def linear_scan(
    a: jax.Array,
    b: jax.Array,
    *,
    axis: int = -1,
    exclusive: bool = False,
    reverse: bool = False,
    method: str = "auto",
    precision: str = "highest",
    initial=None,
    tile_s: int = 128,
    block_tiles: int = 8,
    accum_dtype: Optional[jnp.dtype] = None,
    nonfinite: str = "propagate",
) -> jax.Array:
    """First-order linear recurrence ``y_t = a_t * y_{t-1} + b_t`` along ``axis``.

    The recurrent analogue of :func:`repro.core.scan.scan`: same ``method=``
    table, same tile machinery, with the all-ones triangular operand replaced
    by the weighted triangle ``W`` (module docstring).  ``a ≡ 1`` recovers the
    prefix sum; ``b ≡ 0`` with ``initial=1`` recovers the cumulative product
    (:func:`cumprod`).  SSD/Mamba/xLSTM cross-chunk state propagation routes
    through here (:mod:`repro.core.ssd`).

    Args:
        a: Multipliers ``(..., n)`` — broadcast against ``b``.
        b: Additive inputs ``(..., n)`` — broadcast against ``a``.
        axis: Axis to scan along (scans execute over the last axis; others
            are moved there and back).
        exclusive: If true, return the state *entering* each step —
            ``out[t] = y_{t-1}`` with ``out[0] = initial`` (or 0).  Note the
            shift does not apply ``a_t``.
        reverse: Scan from the end (``y_t = a_t * y_{t+1} + b_t``).
        method: ``"auto"`` (default; resolved from the committed tuning table
            by :mod:`repro.core.autotune`) or one of ``METHODS`` (see module
            docstring for what runs).
        precision: Engine feed precision for the ``W @ b`` contractions
            (:mod:`repro.core.precision`, dispatch rule 9) — ``"highest"``
            (fp32, default), ``"compensated"`` (fp16 Ozaki splits of *both*
            operands, documented ulp bound vs ``"vector"``) or ``"fast"``
            (bf16, loose bound).  Applies to both the forward scan and its
            custom-VJP backward recurrence; only fp32 contractions are
            affected.  Explicit ``method="vector"`` rejects a non-default
            value.
        initial: Optional starting state ``y_{-1}`` (scalar or array
            broadcastable to ``a``/``b`` minus the scan axis).  Folded into
            the first step exactly (``b_0 + a_0 * initial``).  Length-1 scans
            then short-circuit to the direct fused multiply-add — bit-
            identical for every method, no kernel launch (the decode-step
            fast path).
        tile_s: Elements per tile row ``s``; a kernel tile covers ``s²``
            elements, the matmul path chunks ``s`` at a time.
        block_tiles: Tiles per block for ``method="blocked"``.
        accum_dtype: Accumulation dtype override; defaults to
            :func:`linrec_accum_dtype_for` of the broadcast input dtype.
        nonfinite: Non-finite input policy (:mod:`repro.core.guards`,
            dispatch rule 10; ``nonfinite_override`` context >
            ``REPRO_NONFINITE`` env > this argument).  ``"propagate"``
            (default) keeps IEEE semantics with zero added ops; ``"raise"``
            rejects non-finite operands (eagerly when concrete, checkified
            under trace); ``"sanitize"`` replaces non-finite elements with
            the affine identity — ``a -> 1``, ``b -> 0`` — so corrupted steps
            pass the running state through unchanged.

    Returns:
        The scanned array (broadcast shape of ``a`` and ``b``) in the
        accumulation dtype.

    Raises:
        ValueError: If ``method``, ``precision`` or ``nonfinite`` is unknown,
            ``axis`` is out of bounds, or an explicit non-default
            ``precision`` is combined with an explicit ``method="vector"``.

    Example:
        >>> import jax.numpy as jnp
        >>> a = jnp.asarray([1.0, 2.0, 0.0, 3.0])
        >>> b = jnp.asarray([1.0, 1.0, 5.0, 1.0])
        >>> [float(v) for v in linear_scan(a, b)]        # y = a*y_prev + b
        [1.0, 3.0, 5.0, 16.0]
        >>> [float(v) for v in linear_scan(jnp.ones(4), jnp.ones(4))]  # cumsum
        [1.0, 2.0, 3.0, 4.0]
        >>> [float(v) for v in linear_scan(a, b, exclusive=True, initial=7.0)]
        [7.0, 8.0, 17.0, 5.0]
    """
    if method != "auto" and method not in METHODS:
        raise ValueError(f"unknown scan method {method!r}; expected one of "
                         f"{METHODS + ('auto',)}")
    if not 2 <= tile_s <= MAX_TILE:
        raise ValueError(
            f"tile_s must be in [2, {MAX_TILE}] (the exponent-normalized "
            f"window-product range), got {tile_s}")
    # Rank-align WITHOUT materializing the broadcast: a decay shared across
    # payload dims (the SSD cross-chunk case) must reach the matmul path
    # unbroadcast so one weighted triangle serves the whole payload batch.
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    nd = max(a.ndim, b.ndim, 1)
    a = a.reshape((1,) * (nd - a.ndim) + a.shape)
    b = b.reshape((1,) * (nd - b.ndim) + b.shape)
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else linrec_accum_dtype_for(jnp.result_type(a.dtype, b.dtype))

    orig_axis = guards.validate_axis(axis, nd, op="linear_scan")
    moved = orig_axis != nd - 1
    if moved:
        a = jnp.moveaxis(a, orig_axis, -1)
        b = jnp.moveaxis(b, orig_axis, -1)
    n = max(a.shape[-1], b.shape[-1])
    if a.shape[-1] != n:  # scan axis must be real on both operands
        a = jnp.broadcast_to(a, a.shape[:-1] + (n,))
    if b.shape[-1] != n:
        b = jnp.broadcast_to(b, b.shape[:-1] + (n,))
    explicit_method = method != "auto"
    method = maybe_resolve(method, "linear_scan", n,
                           jnp.result_type(a.dtype, b.dtype))
    precision = resolve_precision(precision, method=method,
                                  explicit_method=explicit_method)
    nonfinite = guards.resolve_nonfinite(nonfinite)
    a = guards.apply_nonfinite(a, nonfinite, op="linear_scan", identity=1.0)
    b = guards.apply_nonfinite(b, nonfinite, op="linear_scan", identity=0.0)
    full = jnp.broadcast_shapes(a.shape, b.shape)
    # b is output-sized anyway — materialize it (keeps the custom-VJP
    # cotangent shapes trivial); a stays unbroadcast for the shared-W saving.
    b = jnp.broadcast_to(b, full)
    if reverse:
        a = jnp.flip(a, axis=-1)
        b = jnp.flip(b, axis=-1)
    if n == 0:
        out = jnp.zeros(full, acc)
    else:
        a = a.astype(acc)  # float cotangents for the custom VJP below
        b = b.astype(acc)
        if initial is not None:
            init = jnp.asarray(initial, acc)
            b0 = jnp.broadcast_to(b[..., 0] + a[..., 0] * init, full[:-1])
            rest = jnp.broadcast_to(b[..., 1:], full[:-1] + (n - 1,))
            b = jnp.concatenate([b0[..., None], rest], axis=-1)
        if n == 1:
            # y_0 = a_0·initial + b_0 — already folded into b; every method
            # computes exactly this, so skip the dispatch (and any kernel
            # launch) for the stateful-decode single-step case.
            out = jnp.broadcast_to(b, full).astype(acc)
        else:
            out = _linrec_core(a, b, method, tile_s, block_tiles, acc,
                               precision)
        if exclusive:
            if initial is not None:
                init = jnp.asarray(initial, acc)
                init = init[..., None] if init.ndim else init  # + scan axis
                first = jnp.broadcast_to(init, out[..., :1].shape)
            else:
                first = jnp.zeros_like(out[..., :1])
            out = jnp.concatenate([first, out[..., :-1]], axis=-1)
    if reverse:
        out = jnp.flip(out, axis=-1)
    if moved:
        out = jnp.moveaxis(out, -1, orig_axis)
    return out


def cumprod(x: jax.Array, axis: int = -1, **kw) -> jax.Array:
    """Cumulative product along ``axis`` — ``linear_scan`` with ``b = 0``.

    ``y_t = x_t * y_{t-1}`` from ``initial = 1`` is exactly the cumulative
    product, so every ``method=`` runs it on the same tile machinery.

    Args:
        x: Input array.
        axis: Axis to scan along.
        **kw: Forwarded to :func:`linear_scan` (``method=``, ``reverse=``, …).

    Returns:
        Cumulative products in the linrec accumulation dtype.

    Example:
        >>> import jax.numpy as jnp
        >>> [int(v) for v in cumprod(jnp.asarray([1, 2, 3, 4], jnp.int32))]
        [1, 2, 6, 24]
    """
    kw.setdefault("initial", 1.0)
    return linear_scan(x, jnp.zeros_like(x), axis=axis, **kw)


def cummax(x: jax.Array, axis: int = -1, *, method: str = "auto",
           reverse: bool = False, tile_s: int = 128,
           block_tiles: int = 8) -> jax.Array:
    """Cumulative maximum along ``axis`` under the same ``method=`` contract.

    The max-plus (tropical) semiring instance of the tile scan: within a
    chunk the running maximum is a masked ``(s, s)`` reduce (the tropical
    ``A @ U_s``), and chunk maxima propagate through an exclusive carry — the
    same two-level structure as the matmul prefix scan.  ``"vector"`` is
    ``jax.lax.cummax``; the other three methods share the chunked tropical
    contraction (max has no fused Pallas specialization yet — the kernel and
    blocked entries alias the matmul tiling, keeping the validation and
    dtype rules of the dispatch contract).  Output dtype equals the input
    dtype (maximum never widens), and every method is bit-identical.

    Args:
        x: Input array (any ordered dtype).
        axis: Axis to scan along.
        method: One of ``METHODS``.
        reverse: Scan from the end (suffix maxima).
        tile_s: Chunk length for the tropical contraction.
        block_tiles: Accepted for signature compatibility with the other
            dispatched scans; the tropical contraction has no blocked
            specialization, so it is unused.  Unsupported keywords (e.g.
            ``exclusive``) raise ``TypeError`` rather than being silently
            ignored.

    Returns:
        Running maxima, same shape and dtype as ``x``.

    Raises:
        ValueError: If ``method`` is unknown.

    Example:
        >>> import jax.numpy as jnp
        >>> cummax(jnp.asarray([1, 3, 2, 5, 4], jnp.int32)).tolist()
        [1, 3, 3, 5, 5]
    """
    if method != "auto" and method not in METHODS:
        raise ValueError(f"unknown scan method {method!r}; expected one of "
                         f"{METHODS + ('auto',)}")
    if x.ndim:
        method = maybe_resolve(method, "cummax", x.shape[axis % x.ndim], x.dtype)
    if x.dtype == jnp.bool_:  # lax.cummax rejects bool; max == prefix-any
        out = cummax(x.astype(jnp.int8), axis=axis, method=method,
                     reverse=reverse, tile_s=tile_s)
        return out > 0
    orig_axis = axis % max(x.ndim, 1)
    if x.ndim and orig_axis != x.ndim - 1:
        out = cummax(jnp.moveaxis(x, orig_axis, -1), method=method,
                     reverse=reverse, tile_s=tile_s)
        return jnp.moveaxis(out, -1, orig_axis)
    if reverse:
        return jnp.flip(cummax(jnp.flip(x, axis=-1), method=method,
                               tile_s=tile_s), axis=-1)
    n = x.shape[-1]
    if n == 0:
        return x
    if method == "vector":
        return jax.lax.cummax(x, axis=x.ndim - 1)
    lowest = (jnp.iinfo(x.dtype).min if jnp.issubdtype(x.dtype, jnp.integer)
              else jnp.finfo(x.dtype).min)
    q = tile_s
    *lead, _ = x.shape
    pad = (-n) % q
    xp = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)],
                 constant_values=lowest) if pad else x
    nc = xp.shape[-1] // q
    xc = xp.reshape(*lead, nc, q)
    ri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    masked = jnp.where(cj <= ri, xc[..., None, :], jnp.asarray(lowest, x.dtype))
    local = jnp.max(masked, axis=-1)                     # tropical A @ U_s
    chunk_max = local[..., -1]
    carry = jax.lax.cummax(chunk_max, axis=chunk_max.ndim - 1)
    pad_c = [(0, 0)] * len(lead) + [(1, 0)]
    carry = jnp.pad(carry, pad_c, constant_values=lowest)[..., :-1]
    out = jnp.maximum(local, carry[..., None]).reshape(*lead, nc * q)
    return out[..., :n] if pad else out
