"""Guardrails & graceful degradation — ``docs/architecture.md`` dispatch rule 10.

Every public operator (and the serving path) routes through this module's
three layers, in order:

1. **Pre-trace validation** — plain-Python checks on static information
   (axis/ndim bounds, ``bits_per_pass``, probabilities, temperatures,
   ``SegmentedBatch`` offset monotonicity/bounds when the offsets are
   concrete).  These raise ``ValueError``/``TypeError`` *before* tracing, so a
   bad call fails at the call site instead of deep inside a kernel, and they
   add nothing to the traced jaxpr.
2. **The non-finite policy** — ``nonfinite="propagate" | "raise" | "sanitize"``
   on the scan/sampler family, resolved statically exactly like ``method``
   (rule 8) and ``precision`` (rule 9): an active :func:`nonfinite_override`
   context wins, else the ``REPRO_NONFINITE`` environment variable, else the
   call-site argument.  ``"propagate"`` (the default) is PR 7's documented
   IEEE semantics and traces to a jaxpr **identical** to pre-guard code;
   ``"raise"`` rejects non-finite payloads (eagerly when concrete, as a
   checkified assertion under trace); ``"sanitize"`` replaces non-finite
   elements with the operator's identity — and maps all-masked / all-``-inf``
   sampler rows to a **deterministic greedy fallback** instead of undefined
   samples.
3. **Opt-in in-jit assertions** — :func:`guard_check` stages
   ``jax.experimental.checkify`` assertions (offsets sorted, decode
   ``pos < max_len``, finite CDF before the inverse-transform sample) only
   when ``REPRO_CHECKS=1`` or a :func:`checks` context is active.  With checks
   off, :func:`guard_check` is a Python no-op — zero ops in the jaxpr, which
   is what the bench-smoke jaxpr-identity gate asserts.  Staged checks fire
   through :func:`checked`; under a plain ``jax.jit`` they compile to nothing
   (``checkify.debug_check`` semantics), so enabling checks never breaks an
   existing jit call site.

Backend capability probing (:func:`ensure_available`) extends the
warn-once degradation chain of :mod:`repro.core.autotune`: the first
``kernel``/``blocked`` dispatch per (backend, op family, method) lowers a
tiny probe kernel once and, on failure, degrades through the tuning table's
``fallbacks`` entry (else ``"vector"``) with an :class:`ProbeFallbackWarning`
— the same script runs unmodified on CPU/GPU/TPU.

The fault-injection harness (:mod:`repro.analysis.faults`,
``tests/test_faults.py``) asserts that every injected fault lands on one of
the documented contracts above: propagate, eager ``ValueError``, checkified
error, or warn-once degrade.
"""
from __future__ import annotations

import contextlib
import functools
import os
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import (
    CONCRETE_METHODS, OP_ALIASES, TUNED_OPS, AutotuneFallbackWarning,
    _warn_once, load_table,
)

__all__ = [
    "NONFINITE", "ENV_VAR", "CHECKS_ENV_VAR",
    "NonFiniteError", "ProbeFallbackWarning",
    "resolve_nonfinite", "nonfinite_override", "apply_nonfinite",
    "checks", "checks_enabled", "guard_check", "checked",
    "guards_disabled", "guards_active",
    "validate_axis", "validate_bits_per_pass", "validate_probability",
    "validate_temperature", "validate_offsets", "validate_same_shape",
    "validate_positive", "validate_choice", "validate_broadcastable_to",
    "ensure_available", "probe_lowering", "force_probe_failure",
]

NONFINITE = ("propagate", "raise", "sanitize")
ENV_VAR = "REPRO_NONFINITE"
CHECKS_ENV_VAR = "REPRO_CHECKS"


class NonFiniteError(ValueError):
    """Raised by ``nonfinite="raise"`` when a concrete payload is non-finite."""


class ProbeFallbackWarning(AutotuneFallbackWarning):
    """Raised (once per key) when a lowering probe fails and dispatch degrades."""


_NONFINITE_OVERRIDE: List[str] = []
_CHECKS_OVERRIDE: List[bool] = []
_BYPASS: List[bool] = []


def is_concrete(x) -> bool:
    """True when ``x`` is a plain Python value or a non-traced array.

    Concrete values can be validated eagerly (raising at the call site);
    tracers can only be checked in-jit via :func:`guard_check`.

    Example:
        >>> is_concrete(3.5), is_concrete(jnp.asarray([1.0]))
        (True, True)
        >>> bool(jax.jit(is_concrete)(jnp.asarray([1.0])))
        False
    """
    return not isinstance(x, jax.core.Tracer)


def guards_active() -> bool:
    """False inside a :func:`guards_disabled` block, else True."""
    return not _BYPASS


@contextlib.contextmanager
def guards_disabled():
    """Disable the whole guard layer inside the block (bench/test hook).

    Validation helpers, the non-finite policy, staged checks and lowering
    probes all become no-ops, reproducing pre-guard dispatch exactly.  The
    bench-smoke jaxpr-identity gate traces every guarded operator once
    normally and once under this context and asserts the jaxprs are equal —
    the "zero steady-state overhead" acceptance criterion.

    Example:
        >>> with guards_disabled():
        ...     guards_active()
        False
    """
    _BYPASS.append(True)
    try:
        yield
    finally:
        _BYPASS.pop()


# ---------------------------------------------------------------------------
# Non-finite policy (dispatch rule 10, resolution mirrors rules 8/9)
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def nonfinite_override(policy: str):
    """Force every non-finite-policy resolution to ``policy`` inside the block.

    The in-process analogue of the ``REPRO_NONFINITE`` environment variable
    (and it takes precedence over it) — the non-finite counterpart of
    :func:`repro.core.autotune.method_override` and
    :func:`repro.core.precision.precision_override`.

    Args:
        policy: One of ``NONFINITE``.

    Raises:
        ValueError: If ``policy`` is not a known policy.

    Example:
        >>> with nonfinite_override("sanitize"):
        ...     resolve_nonfinite("propagate")
        'sanitize'
    """
    if policy not in NONFINITE:
        raise ValueError(f"unknown nonfinite policy {policy!r}; expected one "
                         f"of {NONFINITE}")
    _NONFINITE_OVERRIDE.append(policy)
    try:
        yield
    finally:
        _NONFINITE_OVERRIDE.pop()


def _env_nonfinite() -> Optional[str]:
    """The ``REPRO_NONFINITE`` forced policy, or ``None``."""
    p = os.environ.get(ENV_VAR)
    if not p:
        return None
    if p not in NONFINITE:
        raise ValueError(f"{ENV_VAR}={p!r} is not a known nonfinite policy; "
                         f"expected one of {NONFINITE}")
    return p


def resolve_nonfinite(policy: str = "propagate") -> str:
    """Resolve the effective non-finite policy for one call (pre-trace).

    Resolution order (``docs/architecture.md`` dispatch rule 10): an active
    :func:`nonfinite_override` context wins, else ``REPRO_NONFINITE``, else
    the call-site ``nonfinite`` argument.  Resolution happens in Python
    before tracing, so the jaxpr of a call is identical to passing the
    resolved policy explicitly.

    Args:
        policy: The caller-supplied ``nonfinite=`` argument.

    Returns:
        One of ``NONFINITE`` (``"propagate"`` inside :func:`guards_disabled`).

    Raises:
        ValueError: If ``policy`` (argument or environment) is unknown.

    Example:
        >>> resolve_nonfinite()
        'propagate'
        >>> resolve_nonfinite("sanitize")
        'sanitize'
    """
    if policy not in NONFINITE:
        raise ValueError(f"unknown nonfinite policy {policy!r}; expected one "
                         f"of {NONFINITE}")
    if _BYPASS:
        return "propagate"
    p = _NONFINITE_OVERRIDE[-1] if _NONFINITE_OVERRIDE else None
    if p is None:
        p = _env_nonfinite()
    if p is None:
        p = policy
    return p


def apply_nonfinite(x: jax.Array, policy: str, *, op: str,
                    identity: float = 0.0) -> jax.Array:
    """Apply a resolved non-finite policy to a float payload.

    * ``"propagate"`` — return ``x`` untouched (adds **zero** ops; PR 7's
      documented IEEE semantics).
    * ``"raise"`` — when ``x`` is concrete, raise :class:`NonFiniteError`
      eagerly if any element is non-finite; under trace, stage a checkified
      assertion (fires through :func:`checked` / a checkified caller, and
      compiles to nothing under a plain ``jit`` — ``debug_check`` semantics).
    * ``"sanitize"`` — replace non-finite elements with ``identity`` (0 for
      additive scans; the linear-recurrence entry passes the affine identity
      per operand: ``a -> 1``, ``b -> 0``).

    Integer/bool payloads are always finite and are returned unchanged under
    every policy.

    Args:
        x: The operator's payload array.
        policy: A **resolved** policy (one of ``NONFINITE``).
        op: Operator name for error messages.
        identity: Replacement value for ``"sanitize"``.

    Returns:
        ``x``, possibly sanitized.

    Raises:
        NonFiniteError: Policy ``"raise"`` with a concrete non-finite payload.

    Example:
        >>> x = jnp.asarray([1.0, jnp.inf, jnp.nan])
        >>> apply_nonfinite(x, "sanitize", op="scan").tolist()
        [1.0, 0.0, 0.0]
        >>> try:
        ...     apply_nonfinite(x, "raise", op="scan")
        ... except NonFiniteError:
        ...     print("rejected")
        rejected
    """
    if _BYPASS or policy == "propagate" \
            or not jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
        return x
    if policy == "raise":
        if is_concrete(x):
            if not bool(np.isfinite(np.asarray(x)).all()):
                raise NonFiniteError(
                    f"{op}: non-finite input under nonfinite='raise' "
                    "(use 'propagate' for IEEE semantics or 'sanitize' for "
                    "the identity-element fallback)")
        else:
            from jax.experimental import checkify
            checkify.debug_check(
                jnp.all(jnp.isfinite(x)),
                f"{op}: non-finite input under nonfinite='raise'")
        return x
    # sanitize
    return jnp.where(jnp.isfinite(x), x, jnp.asarray(identity, x.dtype))


# ---------------------------------------------------------------------------
# Opt-in in-jit assertions (checkify behind REPRO_CHECKS=1 / checks())
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def checks(enable: bool = True):
    """Enable (or force-disable) staged in-jit assertions inside the block.

    The in-process analogue of ``REPRO_CHECKS=1`` (and it takes precedence
    over it).

    Example:
        >>> with checks():
        ...     checks_enabled()
        True
    """
    _CHECKS_OVERRIDE.append(bool(enable))
    try:
        yield
    finally:
        _CHECKS_OVERRIDE.pop()


def checks_enabled() -> bool:
    """Whether :func:`guard_check` assertions are active.

    An active :func:`checks` context wins, else the ``REPRO_CHECKS``
    environment variable (``"1"`` enables); :func:`guards_disabled` forces
    off.

    Example:
        >>> checks_enabled()
        False
    """
    if _BYPASS:
        return False
    if _CHECKS_OVERRIDE:
        return _CHECKS_OVERRIDE[-1]
    return os.environ.get(CHECKS_ENV_VAR, "") == "1"


def guard_check(pred, msg: str) -> None:
    """Assert ``pred`` when checks are enabled; a Python no-op otherwise.

    Pass the predicate as a **thunk** (zero-argument callable) whenever
    computing it would add ops: with checks off, guard_check returns before
    calling it, so the traced jaxpr carries zero extra equations (the
    bench-smoke identity gate relies on this — dead equations are *not*
    eliminated from a traced jaxpr).  With checks on, a concrete predicate
    raises ``jax.experimental.checkify.JaxRuntimeError`` eagerly; a traced
    predicate stages a ``checkify.debug_check`` that fires through
    :func:`checked` (and compiles to nothing under a plain ``jit``).

    Args:
        pred: Boolean scalar (Python bool, array, or tracer) or a
            zero-argument callable returning one.
        msg: Assertion message.

    Example:
        >>> guard_check(lambda: 1 / 0, "never evaluated: checks are off")
        >>> with checks():
        ...     guard_check(True, "fine")
    """
    if not checks_enabled():
        return
    if callable(pred):
        pred = pred()
    from jax.experimental import checkify
    if is_concrete(pred):
        checkify.check(bool(pred), msg)
    else:
        checkify.debug_check(pred, msg)


def checked(fn: Callable) -> Callable:
    """Functionalize ``fn`` so its staged :func:`guard_check` assertions fire.

    Wraps ``fn`` with ``checkify.checkify(errors=user_checks)`` and throws
    the collected error after the call — the harness the fault-injection
    suite (and a user debugging a numeric issue) runs guarded operators
    under.  ``user_checks`` (this layer's :func:`guard_check` assertions)
    rather than ``all_checks``: the automatic index/float instrumentation
    rewrites every scatter in the traced function and does not support the
    batched scatters the radix-sort pipeline stages.
    Compose with ``jit`` as ``jax.jit(checked(fn))`` is **not** supported by
    checkify; use ``checked(jax.jit(fn))`` or checkify first and jit the
    resulting ``(err, out)`` function.

    Args:
        fn: Any traceable callable.

    Returns:
        A callable with the same signature that raises
        ``checkify.JaxRuntimeError`` if any staged check failed.

    Example:
        >>> def f(x):
        ...     guard_check(jnp.all(x > 0), "x must be positive")
        ...     return x * 2
        >>> with checks():
        ...     out = checked(f)(jnp.asarray([1.0, 2.0]))
        >>> out.tolist()
        [2.0, 4.0]
    """
    from jax.experimental import checkify
    cfn = checkify.checkify(fn, errors=checkify.user_checks)

    @functools.wraps(fn)
    def run(*args, **kwargs):
        err, out = cfn(*args, **kwargs)
        err.throw()
        return out

    return run


# ---------------------------------------------------------------------------
# Pre-trace validation helpers (shared by every public entry point)
# ---------------------------------------------------------------------------


def validate_axis(axis: int, ndim: int, *, op: str) -> int:
    """Normalize ``axis`` against ``ndim``, rejecting out-of-range values.

    Python's ``axis % ndim`` silently wraps *any* integer (``axis=5`` on a
    2-D input lands on axis 1) — this is the numpy-style bounds check every
    scan entry point runs instead.

    Args:
        axis: Caller-supplied axis (negative allowed).
        ndim: Rank of the input.
        op: Operator name for the error message.

    Returns:
        ``axis`` normalized into ``[0, ndim)``.

    Raises:
        ValueError: If ``axis`` is outside ``[-ndim, ndim)`` or ``ndim == 0``.

    Example:
        >>> validate_axis(-1, 3, op="scan")
        2
    """
    if _BYPASS:
        return axis % max(ndim, 1)
    if ndim == 0:
        raise ValueError(f"{op}: input is 0-d; scans need at least one axis")
    if not -ndim <= axis < ndim:
        raise ValueError(f"{op}: axis {axis} is out of bounds for a "
                         f"{ndim}-d input (expected -{ndim} <= axis < {ndim})")
    return axis % ndim


def validate_bits_per_pass(bits_per_pass: int, *, op: str) -> int:
    """Reject ``bits_per_pass`` outside ``[1, 8]`` (the radix-2^k contract).

    Example:
        >>> validate_bits_per_pass(4, op="radix_sort")
        4
    """
    if not _BYPASS and not 1 <= int(bits_per_pass) <= 8:
        raise ValueError(f"{op}: bits_per_pass must be in [1, 8], got "
                         f"{bits_per_pass}")
    return int(bits_per_pass)


def validate_positive(value, *, name: str, op: str) -> int:
    """Reject a non-positive integer knob (tile sides, block counts, radices).

    Example:
        >>> validate_positive(128, name="s", op="scan_tiles")
        128
    """
    if not _BYPASS and int(value) < 1:
        raise ValueError(f"{op}: {name} must be >= 1, got {value!r}")
    return int(value)


def validate_choice(value, choices, *, name: str, op: str):
    """Reject a knob outside its closed set (e.g. an unknown kernel variant).

    Without this, an unknown ``variant=`` silently falls through a kernel's
    ``if``/``else`` chain onto whichever branch is last.

    Example:
        >>> validate_choice("scanul1", ("scanul1", "scanu"),
        ...                 name="variant", op="scan_tiles")
        'scanul1'
    """
    if not _BYPASS and value not in choices:
        raise ValueError(f"{op}: {name} must be one of {tuple(choices)}, "
                         f"got {value!r}")
    return value


def validate_broadcastable_to(b_shape, target, *, op: str,
                              name: str = "flags") -> None:
    """Reject a companion operand that does not broadcast to the payload shape.

    Example:
        >>> validate_broadcastable_to((8,), (4, 8), op="seg_scan_tiles")
    """
    if _BYPASS:
        return
    try:
        ok = jnp.broadcast_shapes(tuple(b_shape), tuple(target)) \
            == tuple(target)
    except ValueError:
        ok = False
    if not ok:
        raise ValueError(f"{op}: {name} shape {tuple(b_shape)} does not "
                         f"broadcast to the payload shape {tuple(target)}")


def validate_probability(p, *, name: str = "p", op: str) -> None:
    """Reject a concrete probability outside ``[0, 1]`` (NaN included).

    Traced values pass through (validated in-jit by :func:`guard_check` where
    an entry point stages one).

    Example:
        >>> validate_probability(0.9, op="top_p_sample")
    """
    if _BYPASS or not is_concrete(p):
        return
    v = float(p)
    if not 0.0 <= v <= 1.0:  # NaN fails every comparison -> rejected too
        raise ValueError(f"{op}: {name} must be in [0, 1], got {p!r}")


def validate_temperature(temperature, *, op: str) -> None:
    """Reject a concrete negative or NaN temperature.

    Zero is allowed — the sampler family documents ``temperature == 0`` as
    the deterministic greedy (argmax) limit.

    Example:
        >>> validate_temperature(0.0, op="top_p_sample")
    """
    if _BYPASS or not is_concrete(temperature):
        return
    v = float(temperature)
    if not v >= 0.0 or not np.isfinite(v):
        raise ValueError(f"{op}: temperature must be a finite value >= 0, "
                         f"got {temperature!r}")


def validate_offsets(offsets, n: int, *, op: str):
    """Validate CSR-style segment ``offsets`` against a length-``n`` value array.

    Static structure (rank, integer dtype, segment count) is always checked
    eagerly.  Concrete offsets are additionally checked for the full CSR
    contract — ``offsets[0] == 0``, ``offsets[-1] == n``, non-decreasing —
    with a ``ValueError`` at the call site; traced offsets stage the same
    contract as a checkified assertion (active under ``REPRO_CHECKS=1`` /
    :func:`checks`, fired by :func:`checked`).

    Args:
        offsets: ``(num_segments + 1,)`` int array.
        n: Length of the packed values array.
        op: Operator name for error messages.

    Returns:
        ``offsets`` unchanged.

    Raises:
        ValueError: Static-structure violation, or concrete offsets breaking
            the CSR contract.
        TypeError: Non-integer offsets dtype.

    Example:
        >>> o = jnp.asarray([0, 3, 5], jnp.int32)
        >>> validate_offsets(o, 5, op="segment_scan") is o
        True
    """
    if _BYPASS:
        return offsets
    offsets = jnp.asarray(offsets) if not isinstance(offsets, jax.Array) \
        and not isinstance(offsets, jax.core.Tracer) else offsets
    if offsets.ndim != 1:
        raise ValueError(f"{op}: offsets must be 1-D "
                         f"(num_segments + 1,), got shape {offsets.shape}")
    if offsets.shape[0] < 1:
        raise ValueError(f"{op}: offsets cannot be empty (need at least "
                         "[0] — one entry per segment boundary plus one)")
    if not jnp.issubdtype(offsets.dtype, jnp.integer):
        raise TypeError(f"{op}: offsets must be integer, got "
                        f"{offsets.dtype}")
    if is_concrete(offsets):
        off = np.asarray(offsets)
        if off[0] != 0:
            raise ValueError(f"{op}: offsets[0] must be 0, got {off[0]}")
        if off[-1] != n:
            raise ValueError(f"{op}: offsets[-1] ({off[-1]}) must equal the "
                             f"packed length ({n})")
        if np.any(np.diff(off) < 0):
            raise ValueError(f"{op}: offsets must be non-decreasing, got "
                             f"{off.tolist()}")
    else:
        guard_check(
            lambda: ((offsets[0] == 0) & (offsets[-1] == n)
                     & jnp.all(offsets[1:] >= offsets[:-1])),
            f"{op}: offsets violate the CSR contract (offsets[0] == 0, "
            f"offsets[-1] == n, non-decreasing)")
    return offsets


def validate_same_shape(a_shape: Tuple[int, ...], b_shape: Tuple[int, ...],
                        *, op: str, a_name: str = "x",
                        b_name: str = "flags") -> None:
    """Reject mismatched payload/flag shapes with a call-site error.

    The fused kernels reshape both operands together; a mismatch otherwise
    surfaces as a cryptic reshape/broadcast failure deep inside Pallas.

    Example:
        >>> validate_same_shape((4, 8), (4, 8), op="split")
    """
    if not _BYPASS and tuple(a_shape) != tuple(b_shape):
        raise ValueError(f"{op}: {a_name} shape {tuple(a_shape)} and "
                         f"{b_name} shape {tuple(b_shape)} must match")


# ---------------------------------------------------------------------------
# Backend capability probe (warn-once degrade for kernel/blocked dispatch)
# ---------------------------------------------------------------------------


# (backend, probe family, method) -> bool (lowering succeeded)
_PROBE_CACHE: dict = {}
_FORCED_FAILURES: List[Tuple[Optional[str], Optional[str]]] = []


def _reset_probes_for_testing() -> None:
    """Clear the probe cache (tests only)."""
    _PROBE_CACHE.clear()


@contextlib.contextmanager
def force_probe_failure(op: Optional[str] = None,
                        method: Optional[str] = None):
    """Make lowering probes fail inside the block (fault-injection hook).

    ``op``/``method`` restrict the simulated failure to one tuned family /
    one of ``("kernel", "blocked")``; ``None`` matches everything.  The probe
    cache is cleared on entry and restored on exit so the simulated failure
    neither sees nor pollutes real probe results.

    Example:
        >>> with force_probe_failure("scan", "kernel"):
        ...     probe_lowering("scan", "kernel")
        False
    """
    saved = dict(_PROBE_CACHE)
    _PROBE_CACHE.clear()
    _FORCED_FAILURES.append((op, method))
    try:
        yield
    finally:
        _FORCED_FAILURES.pop()
        _PROBE_CACHE.clear()
        _PROBE_CACHE.update(saved)


def _probe_family(op: str, method: str) -> str:
    """Collapse an entry-point op onto the kernel family its probe lowers."""
    fam = OP_ALIASES.get(op, op)
    if fam not in TUNED_OPS:
        fam = "scan"
    if method == "blocked" and fam in ("split", "sort", "top_p_sample"):
        # the blocked variants of the §5 operators are built from blocked
        # scans — they share the scan pipeline's probe
        fam = "scan"
    return fam


def _probe_lower(fam: str, method: str) -> None:
    """Lower (without compiling) a tiny instance of the family's kernel."""
    from repro.kernels import ops as kops
    s = 8
    vec = jax.ShapeDtypeStruct((s * s,), jnp.float32)
    flg = jax.ShapeDtypeStruct((s * s,), jnp.int8)
    if fam == "linear_scan":
        if method == "kernel":
            jax.jit(lambda a, b: kops.linrec_kernel(a, b, s=s)).lower(vec, vec)
        else:
            jax.jit(lambda a, b: kops.linrec_blocked_kernel(
                a, b, s=s, block_tiles=2)).lower(vec, vec)
    elif fam == "segment_scan":
        if method == "kernel":
            jax.jit(lambda x, f: kops.seg_scan_kernel(x, f, s=s)).lower(vec, flg)
        else:
            jax.jit(lambda x, f: kops.seg_blocked_scan_kernel(
                x, f, s=s, block_tiles=2)).lower(vec, flg)
    elif fam == "split":
        jax.jit(lambda x, f: kops.split_kernel(x, f, s=s)).lower(vec, flg)
    elif fam == "sort":
        enc = jax.ShapeDtypeStruct((s * s,), jnp.int32)
        jax.jit(lambda e: kops.radix_sort_enc_kernel(
            e, bits=8, bits_per_pass=4, s=s)).lower(enc)
    elif fam == "top_p_sample":
        u = jax.ShapeDtypeStruct((1,), jnp.float32)
        jax.jit(lambda sp, uu: kops.topp_mask_sample_kernel(
            sp, uu, p=0.9)).lower(vec, u)
    else:  # scan
        if method == "kernel":
            jax.jit(lambda x: kops.scan_kernel(x, s=s)).lower(vec)
        else:
            jax.jit(lambda x: kops.blocked_scan_kernel(
                x, s=s, block_tiles=2)).lower(vec)


def probe_lowering(op: str, method: str, *,
                   backend: Optional[str] = None) -> bool:
    """Whether ``method`` for ``op`` lowers on ``backend`` (cached per family).

    The probe traces and **lowers** (never compiles) a tiny instance of the
    family's Pallas kernel under ``jax.jit(...).lower`` — lowering is where
    an unsupported backend/mode combination fails (e.g. forcing
    ``interpret=False`` on CPU), and it costs milliseconds-to-sub-second
    once per (backend, family, method) per process.

    Args:
        op: Entry-point operator name.
        method: ``"kernel"`` or ``"blocked"``.
        backend: Backend name; defaults to ``jax.default_backend()``.

    Returns:
        True when the probe lowers (or has lowered before); False on failure
        (cached, so the attempt happens once).

    Example:
        >>> probe_lowering("scan", "kernel", backend=jax.default_backend())
        True
    """
    if backend is None:
        backend = jax.default_backend()
    fam = _probe_family(op, method)
    for f_op, f_method in _FORCED_FAILURES:
        if (f_op is None or _probe_family(f_op, method) == fam) and \
                (f_method is None or f_method == method):
            return False
    key = (backend, fam, method)
    if key in _PROBE_CACHE:
        return _PROBE_CACHE[key]
    _PROBE_CACHE[key] = True  # recursion guard: a re-entrant probe passes
    try:
        _probe_lower(fam, method)
        ok = True
    except Exception:  # lowering errors are backend/version specific
        ok = False
    _PROBE_CACHE[key] = ok
    return ok


def _fallback_method(op: str) -> str:
    """The method a failed probe degrades to (table ``fallbacks``, else vector)."""
    fam = OP_ALIASES.get(op, op)
    table = load_table() or {}
    fb = table.get("fallbacks", {}).get(fam)
    if fb in CONCRETE_METHODS and fb not in ("kernel", "blocked"):
        return fb
    return "vector"


def ensure_available(method: str, op: str, *,
                     backend: Optional[str] = None) -> str:
    """Degrade ``kernel``/``blocked`` dispatch when the backend can't lower it.

    Called by :func:`repro.core.autotune.maybe_resolve` on every concrete
    resolution, so explicit ``method="kernel"`` calls and table-resolved
    ``"auto"`` calls degrade identically — the same script runs unmodified on
    a backend without Pallas support.  The degradation warns **once** per
    (backend, family, method) with :class:`ProbeFallbackWarning` and resolves
    through the tuning table's ``fallbacks`` entry (else ``"vector"``),
    extending the rule-8 warn-once taxonomy.

    Args:
        method: A **concrete** method (never ``"auto"``).
        op: Entry-point operator name.
        backend: Backend name; defaults to ``jax.default_backend()``.

    Returns:
        ``method``, or its fallback when the probe fails.

    Example:
        >>> ensure_available("matmul", "scan")   # XLA methods never probe
        'matmul'
        >>> ensure_available("kernel", "scan")   # lowers on every CI backend
        'kernel'
    """
    if _BYPASS or method not in ("kernel", "blocked"):
        return method
    if backend is None:
        backend = jax.default_backend()
    if probe_lowering(op, method, backend=backend):
        return method
    fb = _fallback_method(op)
    fam = _probe_family(op, method)
    _warn_once(
        f"probe:{backend}:{fam}:{method}",
        f"method={method!r} for op {op!r} does not lower on backend "
        f"{backend!r}; degrading to method={fb!r} (dispatch rule 10 — "
        "probe once, warn once, fall back through the tuning table)",
        category=ProbeFallbackWarning)
    return fb
