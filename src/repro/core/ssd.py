"""Chunked gated-linear-recurrence scan ("SSD") built on the paper's matmul-scan idea.

The recurrence

    h_t = exp(a_t) * h_{t-1} + B_t ⊗ x_t          h: (H, N, P)
    y_t = C_t^T h_t                               y: (H, P)

is an (associative, weighted) scan.  Exactly as the paper computes prefix sums with
``A @ U_s`` tiles on the cube unit, we compute this scan chunkwise so that all the
O(S·Q) work is dense matmuls on the MXU:

  * within-chunk ("diagonal block"):   Y_d = (C B^T ∘ L) X     where
    ``L[i,j] = exp(cs_i - cs_j)`` is the decay analogue of the paper's triangular
    ``U_s`` / ``L⁻_s`` constant matrices (``cs`` = cumsum of ``a_t`` — itself computed
    with the matmul scan);
  * chunk states:                      S_c = (B ∘ decay-to-end)^T X
  * across chunks: a length-``S/Q`` first-order linear recurrence
    ``S_c = d_c * S_{c-1} + s_c`` — routed through
    :func:`repro.core.linrec.linear_scan` under the caller's ``scan_method``,
    so the cross-chunk phase (the MCScan phase-2 analogue) runs on the same
    method table (matmul / vector / kernel / blocked) as every other scan;
  * off-diagonal correction:           Y_o = (C ∘ decay-from-start) H_in.

Used by the Mamba2 blocks (zamba2) and the mLSTM blocks (xlstm).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.linrec import linear_scan
from repro.core.scan import scan as mm_scan

__all__ = ["ssd_scan", "ssd_scan_ref", "mlstm_chunked", "mlstm_ref"]


def _chunk(x: jax.Array, q: int, axis: int = 1) -> jax.Array:
    s = x.shape[axis]
    assert s % q == 0, f"seq {s} not divisible by chunk {q}"
    nc = s // q
    new_shape = x.shape[:axis] + (nc, q) + x.shape[axis + 1:]
    return x.reshape(new_shape)


def ssd_scan(
    x: jax.Array,        # (B, S, H, P)
    a_log: jax.Array,    # (B, S, H)     log decay (<= 0 for stability)
    b_mat: jax.Array,    # (B, S, H, N)
    c_mat: jax.Array,    # (B, S, H, N)
    *,
    chunk: int = 128,
    scan_method: str = "auto",
    precision: str = "highest",
    initial_state: Optional[jax.Array] = None,   # (B, H, N, P)
    return_final_state: bool = False,
):
    """Chunked SSD scan.  Returns y (B,S,H,P) [and final state (B,H,N,P)].

    ``precision`` (dispatch rule 9) rides into the two scan-shaped phases —
    the log-decay cumsum and the cross-chunk ``linear_scan`` — which resolve
    it against ``scan_method`` exactly as their direct callers would; the
    dense within-chunk einsums always contract in fp32.
    """
    bsz, s, h, p = x.shape
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    xc = _chunk(x, q)                                   # (B,nc,Q,H,P)
    ac = jnp.moveaxis(_chunk(a_log, q), 3, 2)           # (B,nc,H,Q)
    bc = _chunk(b_mat, q)                               # (B,nc,Q,H,N)
    cc = _chunk(c_mat, q)

    # cumsum of log-decays — with the paper's matmul scan (this is literally a
    # prefix sum on the MXU).
    cs = mm_scan(ac.astype(jnp.float32), axis=-1, method=scan_method,
                 precision=precision)                   # (B,nc,H,Q)

    # Within-chunk decay matrix L[i,j] = exp(cs_i - cs_j), i >= j.  Mask BEFORE the
    # exp: for i<j the difference is positive and can overflow, and inf in the dead
    # branch of where() poisons the gradient (inf * 0 = NaN).
    li = cs[..., :, None] - cs[..., None, :]            # (B,nc,H,Q,Q)
    causal = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.exp(jnp.where(causal, li, -1e30))

    scores = jnp.einsum("bnqhd,bnkhd->bnhqk", cc, bc)   # C_i · B_j
    y_diag = jnp.einsum("bnhqk,bnhqk,bnkhp->bnqhp",
                        scores.astype(jnp.float32), lmat,
                        xc.astype(jnp.float32))

    # Chunk states S_c = Σ_j exp(cs_last - cs_j) B_j ⊗ x_j.
    decay_to_end = jnp.exp(cs[..., -1:] - cs)           # (B,nc,H,Q)
    s_c = jnp.einsum("bnhq,bnqhd,bnqhp->bnhdp",
                     decay_to_end, bc.astype(jnp.float32), xc.astype(jnp.float32))

    # Across-chunk first-order linear recurrence (the MCScan phase-2
    # analogue): S_c = d_c * S_{c-1} + s_c, dispatched through the shared
    # method table instead of a hand-rolled associative_scan.  The initial
    # state folds into the recurrence exactly (b_0 + a_0 * init).
    d_c = jnp.exp(cs[..., -1])                          # (B,nc,H) total chunk decay
    init = (initial_state.astype(jnp.float32)
            if initial_state is not None else None)
    nc = d_c.shape[1]
    s_inc = linear_scan(d_c[..., None, None], s_c, axis=1,
                        method=scan_method, initial=init,
                        tile_s=min(128, max(2, nc)), precision=precision)
    # State entering chunk c = inclusive state after chunk c-1 (shift right;
    # the first chunk enters with the initial state, if any).
    h0 = (init[:, None] if init is not None
          else jnp.zeros_like(s_inc[:, :1]))
    h_in = jnp.concatenate(
        [jnp.broadcast_to(h0, s_inc[:, :1].shape), s_inc[:, :-1]], axis=1)

    y_off = jnp.einsum("bnhq,bnqhd,bnhdp->bnqhp",
                       jnp.exp(cs), cc.astype(jnp.float32), h_in)
    y = (y_diag + y_off).reshape(bsz, s + pad, h, p)[:, :s]
    if return_final_state:
        return y.astype(x.dtype), s_inc[:, -1]
    return y.astype(x.dtype)


def ssd_scan_ref(x, a_log, b_mat, c_mat, *, initial_state=None,
                 return_final_state: bool = False):
    """Sequential oracle for :func:`ssd_scan` (lax.scan over time)."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    h0 = (jnp.zeros((bsz, h, n, p), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(hprev, t):
        xt, at, bt, ct = t
        hnew = jnp.exp(at)[..., None, None] * hprev + jnp.einsum(
            "bhd,bhp->bhdp", bt, xt)
        yt = jnp.einsum("bhd,bhdp->bhp", ct, hnew)
        return hnew, yt

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(a_log, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b_mat, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c_mat, 1, 0).astype(jnp.float32))
    hfin, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    if return_final_state:
        return y, hfin
    return y


# ---------------------------------------------------------------------------
# mLSTM (xLSTM's matrix-memory cell) on top of the same chunked machinery
# ---------------------------------------------------------------------------
#
# Cell:  C_t = f_t C_{t-1} + i_t k_t v_t^T ;  n_t = f_t n_{t-1} + i_t k_t
#        h_t = (C_t^T q_t) / (|n_t^T q_t| + eps)
# with f_t = sigmoid(f_pre), i_t = exp(i_pre).  We stabilise with a per-(batch,head)
# global shift M = max(i_pre): both C and n scale by exp(-M), which cancels in the
# division, so the chunked (global-shift) and stepwise-decode (running-max shift)
# paths agree to fp tolerance.  Documented deviation from the xLSTM reference: we use
# a scale-invariant ``|den| + eps`` denominator instead of the scale-*dependent*
# ``max(|den|, 1)`` floor (see DESIGN.md §2).


def mlstm_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                  i_pre: jax.Array, f_pre: jax.Array, *,
                  chunk: int = 128, scan_method: str = "auto",
                  precision: str = "highest") -> jax.Array:
    """q,k,v: (B,S,H,D); i_pre,f_pre: (B,S,H).  Returns (B,S,H,D)."""
    d = q.shape[-1]
    f_log = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    m = jnp.max(i_pre.astype(jnp.float32), axis=1, keepdims=True)      # (B,1,H)
    gain = jnp.exp(i_pre.astype(jnp.float32) - m)                       # stabilised i_t
    qs = q.astype(jnp.float32) / jnp.sqrt(d)
    # numerator: SSD scan with x = gain * v, B = k, C = q
    num = ssd_scan(v.astype(jnp.float32) * gain[..., None], f_log,
                   k.astype(jnp.float32), qs, chunk=chunk,
                   scan_method=scan_method, precision=precision)
    # normaliser: same recurrence with x = gain (P = 1)
    den = ssd_scan(gain[..., None], f_log, k.astype(jnp.float32), qs,
                   chunk=chunk, scan_method=scan_method,
                   precision=precision)[..., 0]
    h = num / (jnp.abs(den) + 1e-6)[..., None]
    return h.astype(q.dtype)


def mlstm_ref(q, k, v, i_pre, f_pre):
    """Sequential oracle with the identical (global-shift) stabilisation."""
    bsz, s, h, d = q.shape
    f_log = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    m = jnp.max(i_pre.astype(jnp.float32), axis=1, keepdims=True)
    gain = jnp.exp(i_pre.astype(jnp.float32) - m)
    qs = q.astype(jnp.float32) / jnp.sqrt(d)

    def step(carry, t):
        c, n = carry
        qt, kt, vt, gt, ft = t
        fgate = jnp.exp(ft)[..., None, None]
        c = fgate * c + jnp.einsum("bhd,bhp->bhdp", kt, vt * gt[..., None])
        n = fgate[..., 0] * n + kt * gt[..., None]
        den = jnp.einsum("bhd,bhd->bh", n, qt)
        num = jnp.einsum("bhd,bhdp->bhp", qt, c)
        y = num / (jnp.abs(den) + 1e-6)[..., None]
        return (c, n), y

    xs = tuple(jnp.moveaxis(a, 1, 0).astype(jnp.float32)
               for a in (qs, k, v, gain, f_log))
    init = (jnp.zeros((bsz, h, d, v.shape[-1]), jnp.float32),
            jnp.zeros((bsz, h, d), jnp.float32))
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(q.dtype)
