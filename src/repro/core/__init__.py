"""Core — the paper's contribution: matmul-based parallel scan + scan-based operators."""
from repro.core.autotune import (
    resolve_method, maybe_resolve, method_override, AutotuneFallbackWarning,
)
from repro.core.guards import (
    NONFINITE, NonFiniteError, ProbeFallbackWarning, checked, checks,
    checks_enabled, force_probe_failure, guards_disabled, nonfinite_override,
    probe_lowering, resolve_nonfinite,
)
from repro.core.precision import (
    PRECISIONS, precision_override, resolve_precision,
)
from repro.core.scan import (
    scan, cumsum, tile_scan_scanu, tile_scan_scanul1, upper_ones,
    strictly_lower_ones, accum_dtype_for,
)
from repro.core.distributed import mcscan, mcscan_local
from repro.core.dist_ops import (
    dist_linear_scan, dist_radix_sort, dist_segment_scan, dist_sort,
    dist_top_p_sample, dist_topk,
)
from repro.core.linrec import linear_scan, cumprod, cummax, linrec_accum_dtype_for
from repro.core.primitives import (
    split, multi_split, compress, radix_sort, sort, topk, top_p_sample,
    weighted_sample,
)
from repro.core.segmented import (
    SegmentedBatch, boundary_flags, segment_ids, segment_scan, segment_cumsum,
    segment_sums, segment_softmax, segment_compress, segment_sort,
    segment_topk, segment_top_p_sample, segment_linear_scan,
)
from repro.core.ssd import ssd_scan, ssd_scan_ref, mlstm_chunked, mlstm_ref
