"""MCScan — the paper's multi-core scan (Alg. 3), mapped to a multi-chip TPU mesh.

Paper structure (SSA with *recomputation*):

  Phase 1 (parallel over blocks):
    * cube units:   tile-local matmul scans of the block  -> written to GM
    * vector units: **recompute** the block reduction r_i  -> written to r in GM
  SyncAll
  Phase 2: each block scans r locally and broadcast-adds its exclusive prefix.

TPU mapping (DESIGN.md §2): a "block" is one device's shard under ``shard_map``.
The block reduction is issued as an *independent* ``jnp.sum`` (not the last element of
the local scan), so the ``all_gather`` of the B block sums has no data dependency on
the matmul scan — XLA's latency-hiding scheduler overlaps the collective with the scan
compute, which is precisely the paper's cube/vector phase-1 overlap.  Global traffic is
2N + B elements, matching the paper's analysis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils.compat import axis_size, shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.scan import scan as _scan, accum_dtype_for

__all__ = ["mcscan_local", "mcscan"]


def mcscan_local(
    x: jax.Array,
    axis_name: str,
    *,
    method: str = "matmul",
    variant: str = "scanul1",
    tile_s: int = 128,
    exclusive: bool = False,
    accum_dtype=None,
) -> jax.Array:
    """Per-device body of MCScan; call inside ``shard_map``.

    ``x`` is the local shard, contiguous along the scanned (last) axis.
    """
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else accum_dtype_for(x.dtype)
    # Phase 1 "vector units": recomputed block reduction, independent of the scan.
    r_local = jnp.sum(x.astype(acc), axis=-1)
    r = jax.lax.all_gather(r_local, axis_name)              # (B, ...) block sums
    num_blocks = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    before = (jnp.arange(num_blocks) < idx).astype(acc)
    offset = jnp.tensordot(before, r.astype(acc), axes=(0, 0))   # exclusive block prefix
    # Phase 1 "cube units": tile-local matmul scans (overlaps with the all_gather).
    y_local = _scan(
        x, axis=-1, method=method, variant=variant, tile_s=tile_s,
        exclusive=exclusive, accum_dtype=acc,
    )
    if exclusive:
        # exclusive local scan already dropped x[..., -1]; the block offset is the
        # same as in the inclusive case.
        pass
    return y_local + offset[..., None]


def mcscan(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str = "data",
    *,
    method: str = "matmul",
    variant: str = "scanul1",
    tile_s: int = 128,
    exclusive: bool = False,
    accum_dtype=None,
    batch_axis_name: Optional[str] = None,
) -> jax.Array:
    """Scan the last axis of ``x``, sharded over ``axis_name`` of ``mesh``.

    ``batch_axis_name`` optionally shards leading (batch) dims over a second mesh axis
    — the batched-scan scheduling of paper §4.2.
    """
    nd = x.ndim
    spec = [None] * nd
    spec[-1] = axis_name
    if batch_axis_name is not None and nd >= 2:
        spec[0] = batch_axis_name
    pspec = P(*spec)

    def body(xl):
        return mcscan_local(
            xl, axis_name, method=method, variant=variant, tile_s=tile_s,
            exclusive=exclusive, accum_dtype=accum_dtype,
        )

    fn = shard_map(body, mesh=mesh, in_specs=pspec, out_specs=pspec)
    return fn(x)
