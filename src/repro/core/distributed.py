"""MCScan — the paper's multi-core scan (Alg. 3), mapped to a multi-chip TPU mesh.

Paper structure (SSA with *recomputation*):

  Phase 1 (parallel over blocks):
    * cube units:   tile-local matmul scans of the block  -> written to GM
    * vector units: **recompute** the block reduction r_i  -> written to r in GM
  SyncAll
  Phase 2: each block scans r locally and broadcast-adds its exclusive prefix.

TPU mapping (DESIGN.md §2): the algorithm is applied twice, at two levels.

* **Across devices** (this module): a "block" is one device's shard under
  ``shard_map``.  The block reduction is issued as an *independent* ``jnp.sum``
  (not the last element of the local scan), so the ``all_gather`` of the B
  block sums has no data dependency on the local scan — XLA's latency-hiding
  scheduler overlaps the collective with the scan compute, which is precisely
  the paper's cube/vector phase-1 overlap.  Global traffic is 2N + B elements,
  matching the paper's analysis.
* **Within a device** (default ``method="blocked"``): the local shard runs the
  same three-phase pipeline as fused Pallas grid kernels
  (``repro.kernels.scan_pipeline``) — per-block matmul partial scans, a
  block-sum carry scan, and a carry broadcast-add fused into the scan launch,
  so each element is read and written once.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils.compat import axis_size, shard_map, shard_map_unchecked
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.scan import scan as _scan, accum_dtype_for

__all__ = ["mcscan_local", "mcscan"]


def mcscan_local(
    x: jax.Array,
    axis_name: str,
    *,
    method: str = "blocked",
    variant: str = "scanul1",
    tile_s: int = 128,
    block_tiles: int = 8,
    exclusive: bool = False,
    accum_dtype=None,
) -> jax.Array:
    """Per-device body of MCScan; call inside ``shard_map``.

    Implements one grid step of paper Alg. 3: the independent block-reduction
    recompute + ``all_gather`` (phase 1, vector units, overlapped by the
    scheduler), the exclusive block-prefix matvec (phase 2), and the local scan
    of the shard (phase 1, cube units) with the carry added (phase 3).

    Args:
        x: The local shard, ``(..., n_local)``, contiguous along the scanned
            (last) axis.  Any dtype :func:`repro.core.scan.accum_dtype_for`
            knows (int8 masks accumulate in int32, bf16/f16 in fp32).
        axis_name: Mesh axis the scanned dimension is sharded over.
        method: Local scan strategy (see :func:`repro.core.scan.scan`); the
            default ``"blocked"`` is the fused three-phase Pallas pipeline.
        variant: Tile algebra, ``"scanu"`` or ``"scanul1"``.
        tile_s: Tile side ``s`` for the matmul scans.
        block_tiles: Tiles per block for ``method="blocked"``.
        exclusive: If true, the local scan is exclusive (the block offset is
            unchanged — it is the sum of *whole* preceding shards).
        accum_dtype: Accumulation dtype override; defaults to
            ``accum_dtype_for(x.dtype)``.

    Returns:
        The globally-scanned local shard, same shape as ``x``, in the
        accumulation dtype.
    """
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else accum_dtype_for(x.dtype)
    # Phase 1 "vector units": recomputed block reduction, independent of the scan.
    r_local = jnp.sum(x.astype(acc), axis=-1)
    r = jax.lax.all_gather(r_local, axis_name)              # (B, ...) block sums
    num_blocks = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    before = (jnp.arange(num_blocks) < idx).astype(acc)
    offset = jnp.tensordot(before, r.astype(acc), axes=(0, 0))   # exclusive block prefix
    # Phase 1 "cube units": the fused per-device scan pipeline (overlaps with
    # the all_gather) — phase 3's carry add for the *local* blocks is already
    # fused inside it; the cross-device offset is added here.
    y_local = _scan(
        x, axis=-1, method=method, variant=variant, tile_s=tile_s,
        block_tiles=block_tiles, exclusive=exclusive, accum_dtype=acc,
    )
    return y_local + offset[..., None]


def mcscan(
    x: jax.Array,
    mesh: Mesh,
    axis_name: str = "data",
    *,
    method: str = "blocked",
    variant: str = "scanul1",
    tile_s: int = 128,
    block_tiles: int = 8,
    exclusive: bool = False,
    accum_dtype=None,
    batch_axis_name: Optional[str] = None,
) -> jax.Array:
    """Scan the last axis of ``x``, sharded over ``axis_name`` of ``mesh``.

    The paper's multi-core scan with a device as the "core": each device runs
    the fused blocked pipeline on its shard while the B block sums travel in a
    single small ``all_gather``, giving 2N + B global traffic.

    Args:
        x: Global array ``(..., n)``; the last axis must divide evenly over
            ``axis_name`` (standard ``shard_map`` sharding rules).
        mesh: Device mesh to shard over.
        axis_name: Mesh axis for the scanned (last) dimension.
        method: Per-device scan strategy (default ``"blocked"``, the fused
            pipeline; see :func:`repro.core.scan.scan` for the full contract).
        variant: Tile algebra, ``"scanu"`` or ``"scanul1"``.
        tile_s: Tile side ``s`` for the matmul scans.
        block_tiles: Tiles per block for ``method="blocked"``.
        exclusive: If true, compute the exclusive global scan.
        accum_dtype: Accumulation dtype override; defaults to
            ``accum_dtype_for(x.dtype)``.
        batch_axis_name: Optionally shard leading (batch) dims over a second
            mesh axis — the batched-scan scheduling of paper §4.2.

    Returns:
        The globally-scanned array, same shape as ``x``, in the accumulation
        dtype.

    Example:
        >>> import jax.numpy as jnp
        >>> from repro.utils.compat import make_mesh
        >>> mesh = make_mesh((1,), ("data",))
        >>> out = mcscan(jnp.ones((1, 8), jnp.int8), mesh, "data", tile_s=2)
        >>> out.dtype.name, [int(v) for v in out[0]]
        ('int32', [1, 2, 3, 4, 5, 6, 7, 8])
    """
    nd = x.ndim
    spec = [None] * nd
    spec[-1] = axis_name
    if batch_axis_name is not None and nd >= 2:
        spec[0] = batch_axis_name
    pspec = P(*spec)

    # 1-device short-circuit: a trivial mesh would still pay the shard_map
    # wrapping and a degenerate (1, ...) all_gather; the local pipeline IS
    # the whole scan there, so skip the collective machinery entirely.
    if mesh.shape[axis_name] == 1 and (
            batch_axis_name is None or mesh.shape[batch_axis_name] == 1):
        return _scan(
            x, axis=-1, method=method, variant=variant, tile_s=tile_s,
            block_tiles=block_tiles, exclusive=exclusive,
            accum_dtype=accum_dtype,
        )

    def body(xl):
        """Run :func:`mcscan_local` on this device's shard."""
        return mcscan_local(
            xl, axis_name, method=method, variant=variant, tile_s=tile_s,
            block_tiles=block_tiles, exclusive=exclusive, accum_dtype=accum_dtype,
        )

    # pallas_call has no replication rule, so the Pallas-launching methods need
    # the check disabled; the pure-XLA paths keep the safer checked shard_map.
    sm = shard_map_unchecked if method in ("kernel", "blocked") else shard_map
    fn = sm(body, mesh=mesh, in_specs=pspec, out_specs=pspec)
    return fn(x)
