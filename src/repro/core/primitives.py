"""Scan-based operators (paper §5): split, sort, top-k/top-p over one dispatch table.

Every operator takes ``method=`` and routes through a single table:

* ``"matmul"`` — the paper's cube-unit scan (ScanU/ScanUL1) feeding unfused
  JAX gather/scatter (default).
* ``"vector"`` — the plain ``jnp.cumsum`` vector baseline, same surrounding ops.
* ``"kernel"`` — the fused Pallas kernels (``repro.kernels.split_mm``): mask
  scan, offsets and permutation in a single launch per batch row.
* ``"blocked"`` — the unfused operators running their scans on the three-phase
  blocked pipeline of paper §4 (``repro.kernels.scan_pipeline``), for large-N
  inputs where read/write-once traffic dominates.

The ``"kernel"`` and ``"blocked"`` paths are bit-identical to ``"vector"`` for
split / multi_split / compress / radix_sort / sort / topk / top_p_sample
(mask-scan offsets are int8 -> int32 and therefore exact; the fused top-p tail
keeps its prefix sums on the VPU cumsum).  The sort-based operators take
``bits_per_pass`` (default 4): each radix pass is a stable ``2^k``-way
``multi_split`` retiring ``k`` bits, so fp32 keys sort in ``32 / k`` passes
instead of 32 — every (method, bits_per_pass) combination stays bit-identical
to ``method="vector"`` with ``bits_per_pass=1`` because bucket offsets remain
exact int8 -> int32 mask scans.

Every operator defaults to ``method="auto"``: the concrete method is resolved
per (op, length, dtype, backend) from the committed tuning table
(:mod:`repro.core.autotune`) before dispatch, in Python on static shapes — so
an ``"auto"`` call traces to a jaxpr identical to passing the resolved method
explicitly, and nested calls (e.g. the ``multi_split`` passes inside
``radix_sort``) always receive the one concrete method the entry point chose.

Shapes are static (JAX): operators that logically return a variable number of
elements (compress/split) return a full-size array plus a count, with the tail
filled.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import guards
from repro.core.autotune import maybe_resolve
from repro.core.scan import METHODS, scan

__all__ = [
    "split", "multi_split", "compress", "radix_sort", "sort", "topk",
    "top_p_sample", "weighted_sample", "float_to_sortable_int",
    "sortable_int_to_float", "dispatch", "METHODS",
]

# METHODS is re-exported from repro.core.scan — one source for the contract.

# Single dispatch table for the §5 operators: {op: {method: impl}}.  "matmul",
# "vector" and "blocked" share the unfused JAX implementations (the scan method
# differs underneath); "kernel" entries are the fused Pallas launches, imported
# lazily so importing repro.core never drags in pallas.
_DISPATCH: Dict[str, Dict[str, Callable]] = {}


def _register(op: str, *methods: str):
    """Register the decorated function as ``op``'s impl for ``methods``."""
    def deco(fn):
        """Add ``fn`` to the dispatch table and return it unchanged."""
        table = _DISPATCH.setdefault(op, {})
        for m in methods:
            table[m] = fn
        return fn
    return deco


def dispatch(op: str, method: str) -> Callable:
    """Look up the implementation of ``op`` for ``method``.

    Args:
        op: Operator name, e.g. ``"split"``, ``"radix_passes"``,
            ``"top_p_tail"``.
        method: One of ``METHODS``.

    Returns:
        The registered implementation callable.

    Raises:
        ValueError: If ``method`` is not in ``METHODS`` or ``op`` has no
            implementation for it.

    Example:
        >>> dispatch("split", "vector").__name__
        '_split_unfused'
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")
    try:
        return _DISPATCH[op][method]
    except KeyError:
        raise ValueError(f"operator {op!r} has no {method!r} implementation") from None


# ---------------------------------------------------------------------------
# shared unfused plumbing: dtype-stable gather + batched destination scatter
# ---------------------------------------------------------------------------


def _take_along_last(x, idx):
    """Gather ``x`` along the last axis with indices widened to int32.

    The single gather helper shared by the unfused operator paths (bucket-base
    lookup in :func:`_multi_split_dest`) and the fused wrappers (the ordering
    gathers in :func:`top_p_sample`): indices are cast to int32 in exactly one
    place, so permutation composition is dtype-stable regardless of how the
    caller produced its index array.

    Args:
        x: Source array ``(..., n)`` (any dtype).
        idx: Integer indices, broadcast-compatible with ``x`` along the last
            axis.

    Returns:
        ``jnp.take_along_axis(x, idx.astype(int32), axis=-1)``.

    Example:
        >>> import jax.numpy as jnp
        >>> _take_along_last(jnp.asarray([10, 20, 30]),
        ...                  jnp.asarray([2, 0, 1], jnp.int8)).tolist()
        [30, 10, 20]
    """
    return jnp.take_along_axis(x, idx.astype(jnp.int32), axis=-1)


def _scatter_payloads(payloads, dest, *, with_indices):
    """Scatter each ``(..., n)`` payload to per-row destinations ``dest``.

    The one scatter used by every unfused split-family operator.  ``dest``
    must be a permutation of ``0..n-1`` per row.  With ``with_indices`` an
    extra int32 array is appended holding the original position of every
    output element (the identity iota is materialised once, not per caller).

    Args:
        payloads: Tuple of arrays shaped like ``dest``.
        dest: int32 destination offsets ``(..., n)``.
        with_indices: Append the original-index permutation to the result.

    Returns:
        Tuple of scattered payloads (same order), plus the permutation last
        when ``with_indices``.
    """
    n = dest.shape[-1]
    iota = jnp.arange(n, dtype=jnp.int32)

    def scatter_1d(dest1, *rows):
        """Scatter one row of every payload (and optionally the iota)."""
        outs = tuple(jnp.zeros_like(r).at[dest1].set(r) for r in rows)
        if with_indices:
            outs += (jnp.zeros((n,), jnp.int32).at[dest1].set(iota),)
        return outs

    batch = dest.shape[:-1]
    if batch:
        flat = [p.reshape(-1, n) for p in payloads]
        outs = jax.vmap(scatter_1d)(dest.reshape(-1, n), *flat)
        return tuple(o.reshape(*batch, n) for o in outs)
    return scatter_1d(dest, *payloads)


# ---------------------------------------------------------------------------
# split / compress
# ---------------------------------------------------------------------------


@_register("split", "matmul", "vector", "blocked")
def _split_unfused(x, flags, *, method, tile_s, interpret):
    """SplitInd via ``scan`` + XLA scatter (the scanned mask lives in HBM)."""
    n = x.shape[-1]
    f8 = flags.astype(jnp.int8)
    ex = scan(f8, axis=-1, exclusive=True, method=method, tile_s=tile_s)
    fl = flags.astype(jnp.int32)
    n_true = ex[..., -1] + fl[..., -1]
    iota = jnp.arange(n, dtype=jnp.int32)
    pos_false = iota - ex                                        # falses before i
    dest = jnp.where(flags, ex, n_true[..., None] + pos_false)
    z, ind = _scatter_payloads((x,), dest, with_indices=True)
    return z, ind, n_true


@_register("split", "kernel")
def _split_fused(x, flags, *, method, tile_s, interpret):
    """SplitInd as one fused Pallas launch per batch row."""
    from repro.kernels import ops as _kops
    return _kops.split_kernel(x, flags, s=tile_s, interpret=interpret)


def split(x: jax.Array, flags: jax.Array, *, method: str = "auto",
          return_indices: bool = True, tile_s: int = 128,
          interpret: Optional[bool] = None):
    """Stable partition (the paper's SplitInd): flagged elements first, order kept.

    The destination offsets come from an exclusive scan of the int8 mask — the
    paper's int8 -> int32 cube-unit mask-scan specialization — so offsets are
    exact integers for every ``method``.

    Args:
        x: Payload array ``(..., n)``, any dtype.
        flags: Boolean array ``(..., n)``; true elements move to the front.
        method: One of ``METHODS`` (``"kernel"`` fuses scan + scatter into one
            launch; ``"blocked"`` runs the mask scan on the §4 pipeline).
        return_indices: If false, omit the permutation from the result.
        tile_s: Tile side ``s`` for the matmul scans.
        interpret: Force Pallas interpret mode (defaults to auto: interpret on
            CPU backends).

    Returns:
        ``(z, indices, n_true)`` — or ``(z, n_true)`` if ``return_indices`` is
        false.  ``z`` is the partitioned payload, ``indices[j]`` the original
        position of ``z[j]`` (int32), ``n_true`` the per-row count of flagged
        elements (int32).

    Example:
        >>> import jax.numpy as jnp
        >>> z, ind, k = split(jnp.asarray([10, 20, 30, 40]),
        ...                   jnp.asarray([False, True, False, True]))
        >>> z.tolist(), ind.tolist(), int(k)
        ([20, 40, 10, 30], [1, 3, 0, 2], 2)
    """
    guards.validate_same_shape(x.shape, jnp.shape(flags), op="split")
    method = maybe_resolve(method, "split", x.shape[-1], x.dtype)
    z, ind, n_true = dispatch("split", method)(
        x, flags, method=method, tile_s=tile_s, interpret=interpret)
    if return_indices:
        return z, ind, n_true
    return z, n_true


def compress(x: jax.Array, mask: jax.Array, *, method: str = "auto",
             fill_value=0, tile_s: int = 128,
             interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Masked select: gather elements where ``mask`` is true, packed left.

    Args:
        x: Payload array ``(..., n)``.
        mask: Boolean array ``(..., n)``.
        method: One of ``METHODS``; forwarded to :func:`split`.
        fill_value: Value for the ``values[count:]`` tail.
        tile_s: Tile side ``s`` for the matmul scans.
        interpret: Force Pallas interpret mode.

    Returns:
        ``(values, count)`` with ``values`` the same shape as ``x`` and
        ``values[..., count:]`` filled with ``fill_value``.

    Example:
        >>> import jax.numpy as jnp
        >>> v, k = compress(jnp.asarray([1, 2, 3, 4]),
        ...                 jnp.asarray([True, False, True, False]))
        >>> v.tolist(), int(k)
        ([1, 3, 0, 0], 2)
    """
    method = maybe_resolve(method, "compress", x.shape[-1], x.dtype)
    z, _, n_true = split(x, mask, method=method, tile_s=tile_s,
                         interpret=interpret)
    iota = jnp.arange(x.shape[-1], dtype=jnp.int32)
    keep = iota < n_true[..., None]
    z = jnp.where(keep, z, jnp.asarray(fill_value, z.dtype))
    return z, n_true


# ---------------------------------------------------------------------------
# multi_split (radix-2^k generalization of SplitInd)
# ---------------------------------------------------------------------------


def _multi_split_dest(digits, num_buckets, *, method, tile_s):
    """Destination offsets for a stable ``num_buckets``-way split.

    One *batched* exclusive :func:`~repro.core.scan.scan` call over the
    ``(..., R, n)`` int8 one-hot digit matrix yields all ``R`` per-bucket mask
    scans at once (the multi-way analogue of the paper's binary SplitInd mask
    scan); per-bucket bases are the tiny ``R``-wide exclusive prefix of the
    bucket counts.

    Args:
        digits: Integer bucket ids ``(..., n)`` in ``[0, num_buckets)``.
        num_buckets: Number of buckets ``R``.
        method: Scan method for the mask scans, one of ``METHODS``.
        tile_s: Tile side ``s`` for the matmul scans.

    Returns:
        ``(dest, counts)`` — int32 destination offsets ``(..., n)`` and int32
        per-bucket counts ``(..., num_buckets)``.
    """
    d32 = digits.astype(jnp.int32)
    buckets = jnp.arange(num_buckets, dtype=jnp.int32)
    oh = (d32[..., None, :] == buckets[:, None]).astype(jnp.int8)  # (..., R, n)
    ex = scan(oh, axis=-1, exclusive=True, method=method, tile_s=tile_s)
    counts = ex[..., -1] + oh[..., -1].astype(jnp.int32)           # (..., R)
    base = jnp.cumsum(counts, axis=-1) - counts                    # R-wide scan
    ex_d = jnp.take_along_axis(ex, d32[..., None, :], axis=-2)[..., 0, :]
    dest = _take_along_last(base, d32) + ex_d
    return dest, counts


@_register("multi_split", "matmul", "vector", "blocked")
def _multi_split_unfused(x, digits, num_buckets, *, method, tile_s, interpret):
    """Multi-way SplitInd via one batched ``scan`` + XLA scatter."""
    dest, counts = _multi_split_dest(digits, num_buckets, method=method,
                                     tile_s=tile_s)
    z, ind = _scatter_payloads((x,), dest, with_indices=True)
    return z, ind, counts


@_register("multi_split", "kernel")
def _multi_split_fused(x, digits, num_buckets, *, method, tile_s, interpret):
    """Multi-way SplitInd as one fused Pallas launch per batch row."""
    from repro.kernels import ops as _kops
    return _kops.multi_split_kernel(x, digits, num_buckets=num_buckets,
                                    s=tile_s, interpret=interpret)


def multi_split(x: jax.Array, digits: jax.Array, num_buckets: int, *,
                method: str = "auto", return_indices: bool = True,
                tile_s: int = 128, interpret: Optional[bool] = None):
    """Stable ``num_buckets``-way partition — radix-2^k SplitInd.

    Generalizes the paper's binary SplitInd: elements are grouped by their
    integer ``digits`` bucket (ascending, original order kept within each
    bucket), with all ``R`` bucket mask scans running as one batched int8 ->
    int32 matmul scan — the TCU-style multi-way split of Dakkak et al. that
    lets one radix pass retire ``log2(R)`` bits.  Offsets are exact integers
    for every ``method``, so all methods are bit-identical.

    Args:
        x: Payload array ``(..., n)``, any dtype.
        digits: Integer array ``(..., n)`` of bucket ids in
            ``[0, num_buckets)`` (values outside the range are undefined
            behaviour).
        num_buckets: Number of buckets ``R >= 1``.
        method: One of ``METHODS`` (``"kernel"`` fuses the one-hot build, the
            batched mask scan, offsets and the scatter into one launch).
        return_indices: If false, omit the permutation from the result.
        tile_s: Tile side ``s`` for the matmul scans.
        interpret: Force Pallas interpret mode (defaults to auto: interpret on
            CPU backends).

    Returns:
        ``(z, indices, counts)`` — or ``(z, counts)`` if ``return_indices``
        is false.  ``z`` is the bucket-grouped payload, ``indices[j]`` the
        original position of ``z[j]`` (int32), ``counts`` the per-bucket
        element counts, shape ``(..., num_buckets)`` (int32).

    Example:
        >>> import jax.numpy as jnp
        >>> z, ind, c = multi_split(jnp.asarray([50, 10, 70, 30]),
        ...                         jnp.asarray([2, 0, 2, 1]), 4)
        >>> z.tolist(), ind.tolist(), c.tolist()
        ([10, 30, 50, 70], [1, 3, 0, 2], [1, 1, 2, 0])
    """
    if num_buckets < 1:
        raise ValueError(f"num_buckets must be >= 1, got {num_buckets}")
    guards.validate_same_shape(x.shape, jnp.shape(digits), op="multi_split",
                               b_name="digits")
    method = maybe_resolve(method, "multi_split", x.shape[-1], x.dtype)
    z, ind, counts = dispatch("multi_split", method)(
        x, digits, num_buckets, method=method, tile_s=tile_s,
        interpret=interpret)
    if return_indices:
        return z, ind, counts
    return z, counts


# ---------------------------------------------------------------------------
# Radix sort (paper §5, LSB; fp16/fp32 via order-preserving bit encodings)
# ---------------------------------------------------------------------------


def float_to_sortable_int(x: jax.Array) -> jax.Array:
    """Order-preserving float -> unsigned encoding (paper's pre-processing phase).

    Positive floats: flip the MSB.  Negative floats: flip all bits.  The
    resulting unsigned integers compare in the same order as the floats.

    Args:
        x: Float array (fp16, bf16 or fp32).

    Returns:
        ``uint16`` (for 16-bit floats) or ``uint32`` (for fp32) keys.

    Raises:
        TypeError: For unsupported float dtypes.

    Example:
        >>> import jax.numpy as jnp
        >>> u = float_to_sortable_int(jnp.asarray([-1.0, 0.0, 1.0], jnp.float32))
        >>> bool(u[0] < u[1] < u[2])
        True
    """
    if x.dtype == jnp.float16:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16)
        sign = (u >> 15).astype(jnp.bool_)
        return jnp.where(sign, ~u, u | jnp.uint16(0x8000))
    if x.dtype == jnp.float32:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)
        sign = (u >> 31).astype(jnp.bool_)
        return jnp.where(sign, ~u, u | jnp.uint32(0x80000000))
    if x.dtype == jnp.bfloat16:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16)
        sign = (u >> 15).astype(jnp.bool_)
        return jnp.where(sign, ~u, u | jnp.uint16(0x8000))
    raise TypeError(f"unsupported float dtype {x.dtype}")


def sortable_int_to_float(u: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`float_to_sortable_int` (paper's post-processing phase).

    Args:
        u: Unsigned keys produced by :func:`float_to_sortable_int`.
        dtype: The original float dtype to decode back to.

    Returns:
        The decoded float array in ``dtype``.

    Raises:
        TypeError: For unsupported float dtypes.

    Example:
        >>> import jax.numpy as jnp
        >>> u = float_to_sortable_int(jnp.asarray([-1.0, 0.5], jnp.float32))
        >>> sortable_int_to_float(u, jnp.float32).tolist()
        [-1.0, 0.5]
    """
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.dtype(jnp.float16), jnp.dtype(jnp.bfloat16)):
        msb = jnp.uint16(0x8000)
        pos = (u & msb).astype(jnp.bool_)
        dec = jnp.where(pos, u & ~msb, ~u)
        return jax.lax.bitcast_convert_type(dec, dtype)
    if dtype == jnp.dtype(jnp.float32):
        msb = jnp.uint32(0x80000000)
        pos = (u & msb).astype(jnp.bool_)
        dec = jnp.where(pos, u & ~msb, ~u)
        return jax.lax.bitcast_convert_type(dec, dtype)
    raise TypeError(f"unsupported float dtype {dtype}")


def _encode_for_sort(x: jax.Array) -> Tuple[jax.Array, int, Callable]:
    """Map ``x`` to unsigned keys; returns ``(keys, n_bits, decode_fn)``."""
    dt = x.dtype
    if jnp.issubdtype(dt, jnp.floating):
        enc = float_to_sortable_int(x)
        bits = enc.dtype.itemsize * 8
        return enc, bits, lambda u: sortable_int_to_float(u, dt)
    if dt in (jnp.dtype(jnp.int16), jnp.dtype(jnp.int32)):
        udt = jnp.uint16 if dt == jnp.dtype(jnp.int16) else jnp.uint32
        bias = jnp.asarray(1 << (jnp.dtype(udt).itemsize * 8 - 1), udt)
        enc = jax.lax.bitcast_convert_type(x, udt) ^ bias
        bits = jnp.dtype(udt).itemsize * 8
        return enc, bits, lambda u: jax.lax.bitcast_convert_type(u ^ bias, dt)
    if dt in (jnp.dtype(jnp.uint16), jnp.dtype(jnp.uint32), jnp.dtype(jnp.uint8),
              jnp.dtype(jnp.int8)):
        if dt == jnp.dtype(jnp.int8):
            enc = (x.astype(jnp.int32) + 128).astype(jnp.uint8)
            return enc, 8, lambda u: (u.astype(jnp.int32) - 128).astype(jnp.int8)
        bits = dt.itemsize * 8
        return x, bits, lambda u: u
    raise TypeError(f"radix sort: unsupported dtype {dt}")


@_register("radix_passes", "matmul", "vector", "blocked")
def _radix_passes_unfused(enc, bits, *, method, tile_s, interpret,
                          bits_per_pass=1):
    """``ceil(bits / k)`` multi-way splits, keys and permutation co-scattered.

    The identity permutation is materialised once (hoisted out of the pass
    loop) and scattered *alongside* the keys through each pass's destination
    offsets — no per-pass iota rebuild and no per-pass gather composition.
    """
    n = enc.shape[-1]
    perm = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), enc.shape)
    work = enc
    for shift in range(0, bits, bits_per_pass):
        k = min(bits_per_pass, bits - shift)
        mask = jnp.asarray((1 << k) - 1, work.dtype)
        digits = ((work >> shift) & mask).astype(jnp.int32)
        dest, _ = _multi_split_dest(digits, 1 << k, method=method,
                                    tile_s=tile_s)
        work, perm = _scatter_payloads((work, perm), dest, with_indices=False)
    return work, perm


@_register("radix_passes", "kernel")
def _radix_passes_fused(enc, bits, *, method, tile_s, interpret,
                        bits_per_pass=1):
    """All radix passes as fused Pallas launches, ``bits_per_pass`` bits each."""
    from repro.kernels import ops as _kops
    return _kops.radix_sort_enc_kernel(enc, bits=bits,
                                       bits_per_pass=bits_per_pass, s=tile_s,
                                       interpret=interpret)


def radix_sort(x: jax.Array, *, descending: bool = False, method: str = "auto",
               return_indices: bool = True, tile_s: int = 128,
               bits_per_pass: int = 4, interpret: Optional[bool] = None):
    """Stable LSB radix sort built on scan-based multi-way splits (paper §5).

    Each pass is a stable ``2^bits_per_pass``-way :func:`multi_split` on a
    ``bits_per_pass``-bit digit, so the key sorts in ``ceil(bits /
    bits_per_pass)`` passes — 8 for fp32 and 4 for bf16/fp16 at the default
    ``bits_per_pass=4``, vs. 32/16 binary splits in the paper's formulation —
    a ``bits_per_pass``-fold cut in HBM round-trips of the (keys, permutation)
    arrays.  ``method="kernel"`` chains digit extraction, the batched matmul
    mask scans and the permutation inside one fused ``radix_pass_multibit``
    launch per digit.  Every (method, bits_per_pass) combination is
    bit-identical: bucket offsets are exact int8 -> int32 mask scans.

    Args:
        x: Keys ``(..., n)``; floats (fp16/bf16/fp32) are sorted via the
            order-preserving bit encoding, ints via a sign-bias encoding.
        descending: Sort high-to-low (stability is preserved by complementing
            the encoded keys).
        method: One of ``METHODS``.
        return_indices: If false, return only the sorted values.
        tile_s: Tile side ``s`` for the mask scans.
        bits_per_pass: Bits retired per radix pass (``1..8``); ``1`` is the
            paper's binary SplitInd formulation, ``4`` the radix-16 default.
            A ragged final digit just uses the remaining bits.
        interpret: Force Pallas interpret mode.

    Returns:
        ``(values, permutation)`` — or just ``values`` if ``return_indices``
        is false.  ``permutation`` is int32 with ``values ==
        take_along_axis(x, permutation, -1)``.

    Raises:
        ValueError: If ``bits_per_pass`` is outside ``[1, 8]``.

    Example:
        >>> import jax.numpy as jnp
        >>> v, idx = radix_sort(jnp.asarray([3, -1, 2, -5], jnp.int8))
        >>> v.tolist(), idx.tolist()
        ([-5, -1, 2, 3], [3, 1, 2, 0])

        ``bits_per_pass`` trades passes for bucket width without changing the
        result — one radix-256 pass equals eight binary passes bit-for-bit:

        >>> x = jnp.asarray([7, 200, 7, 13], jnp.uint8)
        >>> v8, i8 = radix_sort(x, bits_per_pass=8)   # 1 pass of 256 buckets
        >>> v1, i1 = radix_sort(x, bits_per_pass=1)   # 8 binary passes
        >>> v8.tolist() == v1.tolist() == [7, 7, 13, 200]
        True
        >>> i8.tolist() == i1.tolist() == [0, 2, 3, 1]   # stable: first 7 first
        True
    """
    bits_per_pass = guards.validate_bits_per_pass(bits_per_pass,
                                                  op="radix_sort")
    method = maybe_resolve(method, "radix_sort", x.shape[-1], x.dtype)
    enc, bits, decode = _encode_for_sort(x)
    if descending:
        enc = ~enc  # complement keeps stability while reversing the order
    work, perm = dispatch("radix_passes", method)(
        enc, bits, method=method, tile_s=tile_s, interpret=interpret,
        bits_per_pass=min(bits_per_pass, bits))
    if descending:
        work = ~work
    values = decode(work)
    if return_indices:
        return values, perm
    return values


def sort(x: jax.Array, *, descending: bool = False, method: str = "auto",
         tile_s: int = 128, bits_per_pass: int = 4,
         interpret: Optional[bool] = None):
    """PyTorch-style ``sort`` returning ``(values, indices)``; radix under the hood.

    Args:
        x: Keys ``(..., n)`` (see :func:`radix_sort` for supported dtypes).
        descending: Sort high-to-low.
        method: One of ``METHODS``.
        tile_s: Tile side ``s`` for the mask scans.
        bits_per_pass: Bits retired per radix pass (see :func:`radix_sort`).
        interpret: Force Pallas interpret mode.

    Returns:
        ``(values, indices)`` as in :func:`radix_sort`.

    Example:
        >>> import jax.numpy as jnp
        >>> v, i = sort(jnp.asarray([2, 9, 4], jnp.int8), descending=True)
        >>> v.tolist(), i.tolist()
        ([9, 4, 2], [1, 2, 0])
    """
    return radix_sort(x, descending=descending, method=method,
                      return_indices=True, tile_s=tile_s,
                      bits_per_pass=bits_per_pass, interpret=interpret)


# ---------------------------------------------------------------------------
# top-k / top-p / weighted sampling
# ---------------------------------------------------------------------------


def topk(x: jax.Array, k: int, *, method: str = "auto", tile_s: int = 128,
         bits_per_pass: int = 4, interpret: Optional[bool] = None):
    """Top-k via descending radix sort (paper §5 implements it over SplitInd).

    Args:
        x: Keys ``(..., n)``.
        k: Number of leading elements to keep.
        method: One of ``METHODS``.
        tile_s: Tile side ``s`` for the mask scans.
        bits_per_pass: Bits retired per radix pass (see :func:`radix_sort`).
        interpret: Force Pallas interpret mode.

    Returns:
        ``(values, indices)`` of the ``k`` largest elements, sorted descending.

    Example:
        >>> import jax.numpy as jnp
        >>> v, i = topk(jnp.asarray([1, 9, 3, 7], jnp.int8), 2)
        >>> v.tolist(), i.tolist()
        ([9, 7], [1, 3])
    """
    values, idx = radix_sort(x, descending=True, method=method, tile_s=tile_s,
                             bits_per_pass=bits_per_pass, interpret=interpret)
    return values[..., :k], idx[..., :k]


def weighted_sample(w: jax.Array, key: jax.Array, *, method: str = "auto",
                    cdf: Optional[jax.Array] = None, tile_s: int = 128,
                    u: Optional[jax.Array] = None,
                    nonfinite: str = "propagate") -> jax.Array:
    """Inverse-transform sampling on the scanned CDF (paper §5).

    The paper invokes SplitInd with predicate ``scan(w) > θ·Σw`` and reads the
    last output index; counting ``scan(w) <= θ`` is the same index computed
    with the same scan, without the extra data movement.

    Args:
        w: Non-negative weights ``(..., n)`` (need not be normalized).
        key: JAX PRNG key (unused when ``u`` is given).
        method: Scan method for the CDF, one of ``METHODS``.
        cdf: Optional precomputed inclusive scan of ``w`` (skips the scan).
        tile_s: Tile side ``s`` for the matmul scans.
        u: Optional pre-drawn uniforms of shape ``w.shape[:-1] + (1,)``
            overriding the ``key`` draw — deterministic replay and the
            segmented sampler's per-segment parity tests use this.
        nonfinite: Non-finite weight policy (:mod:`repro.core.guards`,
            dispatch rule 10; context > ``REPRO_NONFINITE`` env > argument).
            ``"propagate"`` (default) keeps IEEE semantics; ``"raise"``
            rejects non-finite weights; ``"sanitize"`` zeroes non-finite
            weights and maps degenerate rows (total mass not finite and
            positive) to the deterministic greedy index (argmax of the
            sanitized weights, ties to the first).  Under ``REPRO_CHECKS=1``
            a checkified assertion additionally verifies the CDF is finite
            before the inverse-transform step.

    Returns:
        Sampled indices, shape ``w.shape[:-1]``, int32, in ``[0, n)``.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> int(weighted_sample(jnp.asarray([0.0, 0.0, 1.0]), jax.random.PRNGKey(0)))
        2
        >>> int(weighted_sample(jnp.asarray([1.0, 1.0]), None,
        ...                     u=jnp.asarray([0.75])))
        1
        >>> int(weighted_sample(jnp.asarray([0.2, jnp.nan, 0.1]), None,
        ...                     u=jnp.asarray([0.99]), nonfinite="sanitize"))
        2
    """
    method = maybe_resolve(method, "weighted_sample", w.shape[-1], w.dtype)
    nonfinite = guards.resolve_nonfinite(nonfinite)
    w_eff = w
    if nonfinite == "raise":
        w_eff = guards.apply_nonfinite(w, nonfinite, op="weighted_sample")
    elif nonfinite == "sanitize":
        w_eff = guards.apply_nonfinite(w, nonfinite, op="weighted_sample")
        if w_eff is not w:
            cdf = None  # a caller-supplied CDF no longer matches
    if cdf is None:
        cdf = scan(w_eff, axis=-1, method=method, tile_s=tile_s)
    if jnp.issubdtype(jnp.result_type(cdf), jnp.floating):
        final_cdf = cdf
        guards.guard_check(lambda: jnp.all(jnp.isfinite(final_cdf)),
                           "weighted_sample: non-finite CDF before the "
                           "inverse-transform sample")
    total = cdf[..., -1:]
    if u is None:
        u = jax.random.uniform(key, w.shape[:-1] + (1,), dtype=cdf.dtype)
    theta = u.astype(cdf.dtype) * total
    idx = jnp.sum((cdf < theta).astype(jnp.int32), axis=-1)
    idx = jnp.clip(idx, 0, w.shape[-1] - 1)
    if nonfinite == "sanitize" and jnp.issubdtype(w_eff.dtype, jnp.floating):
        bad = ~(jnp.isfinite(total[..., 0]) & (total[..., 0] > 0))
        greedy = jnp.argmax(w_eff, axis=-1).astype(idx.dtype)
        idx = jnp.where(bad, greedy, idx)
    return idx


def _reject_poisoned_logits(logits: jax.Array) -> jax.Array:
    """``nonfinite="raise"`` for samplers: NaN/+inf and all-``-inf`` rows fail.

    ``-inf`` entries are legitimate vocabulary masks, so plain
    :func:`repro.core.guards.apply_nonfinite` is too strict here: a row is
    poisoned when it carries NaN or ``+inf``, or masks *every* token.
    Concrete logits raise :class:`repro.core.guards.NonFiniteError` eagerly;
    traced logits stage a checkified assertion (fires under
    ``guards.checked`` / ``REPRO_CHECKS=1`` harnesses).
    """
    msg = ("top_p_sample: poisoned logits under nonfinite='raise' (NaN/+inf "
           "entries or a fully masked row)")
    if guards.is_concrete(logits):
        import numpy as np
        arr = np.asarray(logits, dtype=np.float32)
        ok = (~np.isnan(arr).any() and not np.isposinf(arr).any()
              and bool(np.isfinite(arr).any(axis=-1).all()))
        if not ok:
            raise guards.NonFiniteError(msg)
    else:
        from jax.experimental import checkify
        checkify.debug_check(
            ~jnp.any(jnp.isnan(logits)) & ~jnp.any(jnp.isposinf(logits))
            & jnp.all(jnp.any(jnp.isfinite(logits), axis=-1)), msg)
    return logits


@_register("top_p_tail", "matmul", "vector", "blocked")
def _top_p_tail_unfused(sorted_p, key, *, p, method, tile_s, interpret, u=None):
    """Cumsum -> cutoff -> masked renormalised CDF -> inverse-transform sample."""
    cum = scan(sorted_p, axis=-1, method=method, tile_s=tile_s)
    cut = (cum - sorted_p) > p                    # llama3's sample_top_p formula
    masked = jnp.where(cut, 0.0, sorted_p)
    return weighted_sample(masked, key, method=method, tile_s=tile_s, u=u)


@_register("top_p_tail", "kernel")
def _top_p_tail_fused(sorted_p, key, *, p, method, tile_s, interpret, u=None):
    """The whole nucleus-sampling tail as one Pallas launch."""
    from repro.kernels import ops as _kops
    if u is None:
        u = jax.random.uniform(key, sorted_p.shape[:-1] + (1,),
                               dtype=jnp.float32)
    return _kops.topp_mask_sample_kernel(sorted_p, u.astype(jnp.float32), p=p,
                                         interpret=interpret)


def top_p_sample(logits: jax.Array, key: jax.Array, p: float = 0.9,
                 temperature: float = 1.0, *, method: str = "auto",
                 sort_method: str = "radix", tile_s: int = 128,
                 bits_per_pass: int = 4, u: Optional[jax.Array] = None,
                 interpret: Optional[bool] = None,
                 nonfinite: str = "propagate") -> jax.Array:
    """Nucleus sampling exactly as in the paper's Llama3 case study (§5, §6.5).

    Sort (radix, scan-based) -> prefix-sum of sorted probabilities -> mask
    tokens whose *preceding* cumulative mass exceeds ``p`` -> renormalise ->
    weighted sample.  With fp16-style 16-bit keys this is the paper's "17 scans
    per batch row" operator; the default ``bits_per_pass=4`` sorts those keys
    in 4 radix-16 passes instead of 16 binary splits.  ``method="kernel"``
    runs the sort as fused radix passes and the whole sampling tail as one
    Pallas launch.

    Args:
        logits: Unnormalised scores ``(..., vocab)``; softmax is applied in
            fp32.
        key: JAX PRNG key.
        p: Nucleus mass threshold in ``(0, 1]``.
        temperature: Logit divisor applied before the softmax.
        method: One of ``METHODS`` for the sort and sampling scans.
        sort_method: ``"radix"`` (scan-based, on bf16-rounded keys = 16 sort
            bits as in the paper's fp16 evaluation) or ``"xla"`` (baseline
            ``argsort``).
        tile_s: Tile side ``s`` for the mask scans.
        bits_per_pass: Bits retired per radix pass (see :func:`radix_sort`);
            ignored for ``sort_method="xla"``.
        u: Optional pre-drawn uniforms of shape ``logits.shape[:-1] + (1,)``
            overriding the ``key`` draw in the sampling tail (deterministic
            replay; the segmented sampler's parity tests use this).
        interpret: Force Pallas interpret mode.
        nonfinite: Non-finite logit policy (:mod:`repro.core.guards`,
            dispatch rule 10; context > ``REPRO_NONFINITE`` env > argument).
            ``"propagate"`` (default) keeps IEEE semantics — an all-``-inf``
            or NaN-poisoned row yields an undefined sample, exactly as
            before; ``"raise"`` rejects non-finite *upward* logits (``-inf``
            mask entries are legitimate and always allowed); ``"sanitize"``
            maps rows whose softmax degenerates (all masked / all ``-inf`` /
            any NaN) to the deterministic greedy token — argmax over the
            logits with NaNs treated as ``-inf``, ties to the lowest id.

    Returns:
        Sampled token ids, shape ``logits.shape[:-1]``, int32.

    Raises:
        ValueError: If ``p`` (concrete) is outside ``[0, 1]`` or
            ``temperature`` (concrete) is negative or NaN.

    Note:
        ``temperature == 0`` is the documented greedy limit: the call returns
        the deterministic argmax (NaN logits treated as ``-inf``) for every
        ``method`` without tracing the sampling pipeline.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> logits = jnp.asarray([[0.0, 20.0, 0.0, 0.0]])
        >>> int(top_p_sample(logits, jax.random.PRNGKey(1), p=0.9)[0])
        1
        >>> int(top_p_sample(logits, jax.random.PRNGKey(1), temperature=0.0)[0])
        1
    """
    guards.validate_probability(p, op="top_p_sample")
    guards.validate_temperature(temperature, op="top_p_sample")
    nonfinite = guards.resolve_nonfinite(nonfinite)
    if guards.is_concrete(temperature) and float(temperature) == 0.0:
        # the temperature -> 0 limit: all mass on the max logit
        greedy = jnp.where(jnp.isnan(logits), -jnp.inf, logits)
        return jnp.argmax(greedy, axis=-1).astype(jnp.int32)
    method = maybe_resolve(method, "top_p_sample", logits.shape[-1],
                           logits.dtype)
    if nonfinite == "raise":
        # -inf entries are legitimate vocabulary masks; reject NaN and +inf
        logits = _reject_poisoned_logits(logits)
    if temperature != 1.0:
        logits = logits / temperature
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if nonfinite == "sanitize":
        # degenerate rows (all masked / all--inf / NaN-poisoned) have a NaN
        # softmax; give them a one-hot at the deterministic greedy token so
        # the tail (and its staged finite-CDF check) sees a valid
        # distribution and samples the greedy fallback
        bad = ~jnp.all(jnp.isfinite(probs), axis=-1)
        greedy = jnp.argmax(jnp.where(jnp.isnan(logits), -jnp.inf, logits),
                            axis=-1)
        onehot = jax.nn.one_hot(greedy, probs.shape[-1], dtype=probs.dtype)
        probs = jnp.where(bad[..., None], onehot, probs)
    if sort_method == "radix":
        # Sort on bf16-rounded keys (16 bits, as in the paper's fp16
        # evaluation); ties/rounding only reorder within ~3-ulp probability bands.
        keys16 = probs.astype(jnp.bfloat16)
        _, order = radix_sort(keys16, descending=True, method=method,
                              tile_s=tile_s, bits_per_pass=bits_per_pass,
                              interpret=interpret)
    else:
        order = jnp.argsort(-probs, axis=-1)
    sorted_p = _take_along_last(probs, order)
    j = dispatch("top_p_tail", method)(
        sorted_p, key, p=p, method=method, tile_s=tile_s, interpret=interpret,
        u=u)
    tok = _take_along_last(order, j[..., None])[..., 0]
    if nonfinite == "sanitize":
        # belt-and-braces: the one-hot rewrite above makes the tail itself
        # deterministic for repaired rows, but pin the token regardless
        tok = jnp.where(bad, greedy.astype(tok.dtype), tok)
    return tok
