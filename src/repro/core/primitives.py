"""Scan-based operators (paper §5): split, sort, top-k/top-p over one dispatch table.

Every operator takes ``method=`` and routes through a single table:

* ``"matmul"`` — the paper's cube-unit scan (ScanU/ScanUL1) feeding unfused
  JAX gather/scatter (default).
* ``"vector"`` — the plain ``jnp.cumsum`` vector baseline, same surrounding ops.
* ``"kernel"`` — the fused Pallas kernels (``repro.kernels.split_mm``): mask
  scan, offsets and permutation in a single launch per batch row.
* ``"blocked"`` — the unfused operators running their scans on the three-phase
  blocked pipeline of paper §4 (``repro.kernels.scan_pipeline``), for large-N
  inputs where read/write-once traffic dominates.

The ``"kernel"`` and ``"blocked"`` paths are bit-identical to ``"vector"`` for
split / compress / radix_sort / sort / topk / top_p_sample (mask-scan offsets
are int8 -> int32 and therefore exact; the fused top-p tail keeps its prefix
sums on the VPU cumsum).

Shapes are static (JAX): operators that logically return a variable number of
elements (compress/split) return a full-size array plus a count, with the tail
filled.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.scan import METHODS, scan

__all__ = [
    "split", "compress", "radix_sort", "sort", "topk", "top_p_sample",
    "weighted_sample", "float_to_sortable_int", "sortable_int_to_float",
    "dispatch", "METHODS",
]

# METHODS is re-exported from repro.core.scan — one source for the contract.

# Single dispatch table for the §5 operators: {op: {method: impl}}.  "matmul",
# "vector" and "blocked" share the unfused JAX implementations (the scan method
# differs underneath); "kernel" entries are the fused Pallas launches, imported
# lazily so importing repro.core never drags in pallas.
_DISPATCH: Dict[str, Dict[str, Callable]] = {}


def _register(op: str, *methods: str):
    """Register the decorated function as ``op``'s impl for ``methods``."""
    def deco(fn):
        """Add ``fn`` to the dispatch table and return it unchanged."""
        table = _DISPATCH.setdefault(op, {})
        for m in methods:
            table[m] = fn
        return fn
    return deco


def dispatch(op: str, method: str) -> Callable:
    """Look up the implementation of ``op`` for ``method``.

    Args:
        op: Operator name, e.g. ``"split"``, ``"radix_passes"``,
            ``"top_p_tail"``.
        method: One of ``METHODS``.

    Returns:
        The registered implementation callable.

    Raises:
        ValueError: If ``method`` is not in ``METHODS`` or ``op`` has no
            implementation for it.

    Example:
        >>> dispatch("split", "vector").__name__
        '_split_unfused'
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")
    try:
        return _DISPATCH[op][method]
    except KeyError:
        raise ValueError(f"operator {op!r} has no {method!r} implementation") from None


# ---------------------------------------------------------------------------
# split / compress
# ---------------------------------------------------------------------------


@_register("split", "matmul", "vector", "blocked")
def _split_unfused(x, flags, *, method, tile_s, interpret):
    """SplitInd via ``scan`` + XLA scatter (the scanned mask lives in HBM)."""
    n = x.shape[-1]
    f8 = flags.astype(jnp.int8)
    ex = scan(f8, axis=-1, exclusive=True, method=method, tile_s=tile_s)
    fl = flags.astype(jnp.int32)
    n_true = ex[..., -1] + fl[..., -1]
    iota = jnp.arange(n, dtype=jnp.int32)
    pos_false = iota - ex                                        # falses before i
    dest = jnp.where(flags, ex, n_true[..., None] + pos_false)

    def scatter_1d(dest1, x1):
        """Scatter one row's payload and source indices to their destinations."""
        z = jnp.zeros_like(x1).at[dest1].set(x1)
        ind = jnp.zeros((n,), jnp.int32).at[dest1].set(iota)
        return z, ind

    batch = x.shape[:-1]
    if batch:
        flat_dest = dest.reshape(-1, n)
        flat_x = x.reshape(-1, n)
        z, ind = jax.vmap(scatter_1d)(flat_dest, flat_x)
        z = z.reshape(*batch, n)
        ind = ind.reshape(*batch, n)
    else:
        z, ind = scatter_1d(dest, x)
    return z, ind, n_true


@_register("split", "kernel")
def _split_fused(x, flags, *, method, tile_s, interpret):
    """SplitInd as one fused Pallas launch per batch row."""
    from repro.kernels import ops as _kops
    return _kops.split_kernel(x, flags, s=tile_s, interpret=interpret)


def split(x: jax.Array, flags: jax.Array, *, method: str = "matmul",
          return_indices: bool = True, tile_s: int = 128,
          interpret: Optional[bool] = None):
    """Stable partition (the paper's SplitInd): flagged elements first, order kept.

    The destination offsets come from an exclusive scan of the int8 mask — the
    paper's int8 -> int32 cube-unit mask-scan specialization — so offsets are
    exact integers for every ``method``.

    Args:
        x: Payload array ``(..., n)``, any dtype.
        flags: Boolean array ``(..., n)``; true elements move to the front.
        method: One of ``METHODS`` (``"kernel"`` fuses scan + scatter into one
            launch; ``"blocked"`` runs the mask scan on the §4 pipeline).
        return_indices: If false, omit the permutation from the result.
        tile_s: Tile side ``s`` for the matmul scans.
        interpret: Force Pallas interpret mode (defaults to auto: interpret on
            CPU backends).

    Returns:
        ``(z, indices, n_true)`` — or ``(z, n_true)`` if ``return_indices`` is
        false.  ``z`` is the partitioned payload, ``indices[j]`` the original
        position of ``z[j]`` (int32), ``n_true`` the per-row count of flagged
        elements (int32).

    Example:
        >>> import jax.numpy as jnp
        >>> z, ind, k = split(jnp.asarray([10, 20, 30, 40]),
        ...                   jnp.asarray([False, True, False, True]))
        >>> z.tolist(), ind.tolist(), int(k)
        ([20, 40, 10, 30], [1, 3, 0, 2], 2)
    """
    z, ind, n_true = dispatch("split", method)(
        x, flags, method=method, tile_s=tile_s, interpret=interpret)
    if return_indices:
        return z, ind, n_true
    return z, n_true


def compress(x: jax.Array, mask: jax.Array, *, method: str = "matmul",
             fill_value=0, tile_s: int = 128,
             interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Masked select: gather elements where ``mask`` is true, packed left.

    Args:
        x: Payload array ``(..., n)``.
        mask: Boolean array ``(..., n)``.
        method: One of ``METHODS``; forwarded to :func:`split`.
        fill_value: Value for the ``values[count:]`` tail.
        tile_s: Tile side ``s`` for the matmul scans.
        interpret: Force Pallas interpret mode.

    Returns:
        ``(values, count)`` with ``values`` the same shape as ``x`` and
        ``values[..., count:]`` filled with ``fill_value``.

    Example:
        >>> import jax.numpy as jnp
        >>> v, k = compress(jnp.asarray([1, 2, 3, 4]),
        ...                 jnp.asarray([True, False, True, False]))
        >>> v.tolist(), int(k)
        ([1, 3, 0, 0], 2)
    """
    z, _, n_true = split(x, mask, method=method, tile_s=tile_s,
                         interpret=interpret)
    iota = jnp.arange(x.shape[-1], dtype=jnp.int32)
    keep = iota < n_true[..., None]
    z = jnp.where(keep, z, jnp.asarray(fill_value, z.dtype))
    return z, n_true


# ---------------------------------------------------------------------------
# Radix sort (paper §5, LSB; fp16/fp32 via order-preserving bit encodings)
# ---------------------------------------------------------------------------


def float_to_sortable_int(x: jax.Array) -> jax.Array:
    """Order-preserving float -> unsigned encoding (paper's pre-processing phase).

    Positive floats: flip the MSB.  Negative floats: flip all bits.  The
    resulting unsigned integers compare in the same order as the floats.

    Args:
        x: Float array (fp16, bf16 or fp32).

    Returns:
        ``uint16`` (for 16-bit floats) or ``uint32`` (for fp32) keys.

    Raises:
        TypeError: For unsupported float dtypes.

    Example:
        >>> import jax.numpy as jnp
        >>> u = float_to_sortable_int(jnp.asarray([-1.0, 0.0, 1.0], jnp.float32))
        >>> bool(u[0] < u[1] < u[2])
        True
    """
    if x.dtype == jnp.float16:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16)
        sign = (u >> 15).astype(jnp.bool_)
        return jnp.where(sign, ~u, u | jnp.uint16(0x8000))
    if x.dtype == jnp.float32:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)
        sign = (u >> 31).astype(jnp.bool_)
        return jnp.where(sign, ~u, u | jnp.uint32(0x80000000))
    if x.dtype == jnp.bfloat16:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16)
        sign = (u >> 15).astype(jnp.bool_)
        return jnp.where(sign, ~u, u | jnp.uint16(0x8000))
    raise TypeError(f"unsupported float dtype {x.dtype}")


def sortable_int_to_float(u: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`float_to_sortable_int` (paper's post-processing phase).

    Args:
        u: Unsigned keys produced by :func:`float_to_sortable_int`.
        dtype: The original float dtype to decode back to.

    Returns:
        The decoded float array in ``dtype``.

    Raises:
        TypeError: For unsupported float dtypes.

    Example:
        >>> import jax.numpy as jnp
        >>> u = float_to_sortable_int(jnp.asarray([-1.0, 0.5], jnp.float32))
        >>> sortable_int_to_float(u, jnp.float32).tolist()
        [-1.0, 0.5]
    """
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.dtype(jnp.float16), jnp.dtype(jnp.bfloat16)):
        msb = jnp.uint16(0x8000)
        pos = (u & msb).astype(jnp.bool_)
        dec = jnp.where(pos, u & ~msb, ~u)
        return jax.lax.bitcast_convert_type(dec, dtype)
    if dtype == jnp.dtype(jnp.float32):
        msb = jnp.uint32(0x80000000)
        pos = (u & msb).astype(jnp.bool_)
        dec = jnp.where(pos, u & ~msb, ~u)
        return jax.lax.bitcast_convert_type(dec, dtype)
    raise TypeError(f"unsupported float dtype {dtype}")


def _encode_for_sort(x: jax.Array) -> Tuple[jax.Array, int, Callable]:
    """Map ``x`` to unsigned keys; returns ``(keys, n_bits, decode_fn)``."""
    dt = x.dtype
    if jnp.issubdtype(dt, jnp.floating):
        enc = float_to_sortable_int(x)
        bits = enc.dtype.itemsize * 8
        return enc, bits, lambda u: sortable_int_to_float(u, dt)
    if dt in (jnp.dtype(jnp.int16), jnp.dtype(jnp.int32)):
        udt = jnp.uint16 if dt == jnp.dtype(jnp.int16) else jnp.uint32
        bias = jnp.asarray(1 << (jnp.dtype(udt).itemsize * 8 - 1), udt)
        enc = jax.lax.bitcast_convert_type(x, udt) ^ bias
        bits = jnp.dtype(udt).itemsize * 8
        return enc, bits, lambda u: jax.lax.bitcast_convert_type(u ^ bias, dt)
    if dt in (jnp.dtype(jnp.uint16), jnp.dtype(jnp.uint32), jnp.dtype(jnp.uint8),
              jnp.dtype(jnp.int8)):
        if dt == jnp.dtype(jnp.int8):
            enc = (x.astype(jnp.int32) + 128).astype(jnp.uint8)
            return enc, 8, lambda u: (u.astype(jnp.int32) - 128).astype(jnp.int8)
        bits = dt.itemsize * 8
        return x, bits, lambda u: u
    raise TypeError(f"radix sort: unsupported dtype {dt}")


@_register("radix_passes", "matmul", "vector", "blocked")
def _radix_passes_unfused(enc, bits, *, method, tile_s, interpret):
    """One ``split`` per bit; the permutation is composed with a gather."""
    n = enc.shape[-1]
    perm = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), enc.shape)
    work = enc
    one = jnp.asarray(1, enc.dtype)
    for b in range(bits):
        bit = (work >> b) & one
        flags = bit == 0                     # zeros first (LSB ascending pass)
        work, ind, _ = split(work, flags, method=method, tile_s=tile_s,
                             interpret=interpret)
        perm = jnp.take_along_axis(perm, ind, axis=-1)
    return work, perm


@_register("radix_passes", "kernel")
def _radix_passes_fused(enc, bits, *, method, tile_s, interpret):
    """All ``bits`` radix passes as fused Pallas launches."""
    from repro.kernels import ops as _kops
    return _kops.radix_sort_enc_kernel(enc, bits=bits, s=tile_s,
                                       interpret=interpret)


def radix_sort(x: jax.Array, *, descending: bool = False, method: str = "matmul",
               return_indices: bool = True, tile_s: int = 128,
               interpret: Optional[bool] = None):
    """Stable LSB radix sort built on scan-based splits (paper §5).

    One split per bit (16 for fp16/bf16, 32 for fp32), each using the int8 mask
    scan; ``method="kernel"`` chains digit extraction, the matmul split and the
    permutation inside one fused ``radix_pass`` launch per bit.

    Args:
        x: Keys ``(..., n)``; floats (fp16/bf16/fp32) are sorted via the
            order-preserving bit encoding, ints via a sign-bias encoding.
        descending: Sort high-to-low (stability is preserved by complementing
            the encoded keys).
        method: One of ``METHODS``.
        return_indices: If false, return only the sorted values.
        tile_s: Tile side ``s`` for the mask scans.
        interpret: Force Pallas interpret mode.

    Returns:
        ``(values, permutation)`` — or just ``values`` if ``return_indices``
        is false.  ``permutation`` is int32 with ``values ==
        take_along_axis(x, permutation, -1)``.

    Example:
        >>> import jax.numpy as jnp
        >>> v, idx = radix_sort(jnp.asarray([3, -1, 2, -5], jnp.int8))
        >>> v.tolist(), idx.tolist()
        ([-5, -1, 2, 3], [3, 1, 2, 0])
    """
    enc, bits, decode = _encode_for_sort(x)
    if descending:
        enc = ~enc  # complement keeps stability while reversing the order
    work, perm = dispatch("radix_passes", method)(
        enc, bits, method=method, tile_s=tile_s, interpret=interpret)
    if descending:
        work = ~work
    values = decode(work)
    if return_indices:
        return values, perm
    return values


def sort(x: jax.Array, *, descending: bool = False, method: str = "matmul",
         tile_s: int = 128, interpret: Optional[bool] = None):
    """PyTorch-style ``sort`` returning ``(values, indices)``; radix under the hood.

    Args:
        x: Keys ``(..., n)`` (see :func:`radix_sort` for supported dtypes).
        descending: Sort high-to-low.
        method: One of ``METHODS``.
        tile_s: Tile side ``s`` for the mask scans.
        interpret: Force Pallas interpret mode.

    Returns:
        ``(values, indices)`` as in :func:`radix_sort`.

    Example:
        >>> import jax.numpy as jnp
        >>> v, i = sort(jnp.asarray([2, 9, 4], jnp.int8), descending=True)
        >>> v.tolist(), i.tolist()
        ([9, 4, 2], [1, 2, 0])
    """
    return radix_sort(x, descending=descending, method=method,
                      return_indices=True, tile_s=tile_s, interpret=interpret)


# ---------------------------------------------------------------------------
# top-k / top-p / weighted sampling
# ---------------------------------------------------------------------------


def topk(x: jax.Array, k: int, *, method: str = "matmul", tile_s: int = 128,
         interpret: Optional[bool] = None):
    """Top-k via descending radix sort (paper §5 implements it over SplitInd).

    Args:
        x: Keys ``(..., n)``.
        k: Number of leading elements to keep.
        method: One of ``METHODS``.
        tile_s: Tile side ``s`` for the mask scans.
        interpret: Force Pallas interpret mode.

    Returns:
        ``(values, indices)`` of the ``k`` largest elements, sorted descending.

    Example:
        >>> import jax.numpy as jnp
        >>> v, i = topk(jnp.asarray([1, 9, 3, 7], jnp.int8), 2)
        >>> v.tolist(), i.tolist()
        ([9, 7], [1, 3])
    """
    values, idx = radix_sort(x, descending=True, method=method, tile_s=tile_s,
                             interpret=interpret)
    return values[..., :k], idx[..., :k]


def weighted_sample(w: jax.Array, key: jax.Array, *, method: str = "matmul",
                    cdf: Optional[jax.Array] = None,
                    tile_s: int = 128) -> jax.Array:
    """Inverse-transform sampling on the scanned CDF (paper §5).

    The paper invokes SplitInd with predicate ``scan(w) > θ·Σw`` and reads the
    last output index; counting ``scan(w) <= θ`` is the same index computed
    with the same scan, without the extra data movement.

    Args:
        w: Non-negative weights ``(..., n)`` (need not be normalized).
        key: JAX PRNG key.
        method: Scan method for the CDF, one of ``METHODS``.
        cdf: Optional precomputed inclusive scan of ``w`` (skips the scan).
        tile_s: Tile side ``s`` for the matmul scans.

    Returns:
        Sampled indices, shape ``w.shape[:-1]``, int32, in ``[0, n)``.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> int(weighted_sample(jnp.asarray([0.0, 0.0, 1.0]), jax.random.PRNGKey(0)))
        2
    """
    if cdf is None:
        cdf = scan(w, axis=-1, method=method, tile_s=tile_s)
    total = cdf[..., -1:]
    theta = jax.random.uniform(key, w.shape[:-1] + (1,), dtype=cdf.dtype) * total
    idx = jnp.sum((cdf < theta).astype(jnp.int32), axis=-1)
    return jnp.clip(idx, 0, w.shape[-1] - 1)


@_register("top_p_tail", "matmul", "vector", "blocked")
def _top_p_tail_unfused(sorted_p, key, *, p, method, tile_s, interpret):
    """Cumsum -> cutoff -> masked renormalised CDF -> inverse-transform sample."""
    cum = scan(sorted_p, axis=-1, method=method, tile_s=tile_s)
    cut = (cum - sorted_p) > p                    # llama3's sample_top_p formula
    masked = jnp.where(cut, 0.0, sorted_p)
    return weighted_sample(masked, key, method=method, tile_s=tile_s)


@_register("top_p_tail", "kernel")
def _top_p_tail_fused(sorted_p, key, *, p, method, tile_s, interpret):
    """The whole nucleus-sampling tail as one Pallas launch."""
    from repro.kernels import ops as _kops
    u = jax.random.uniform(key, sorted_p.shape[:-1] + (1,), dtype=jnp.float32)
    return _kops.topp_mask_sample_kernel(sorted_p, u, p=p, interpret=interpret)


def top_p_sample(logits: jax.Array, key: jax.Array, p: float = 0.9,
                 temperature: float = 1.0, *, method: str = "matmul",
                 sort_method: str = "radix", tile_s: int = 128,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Nucleus sampling exactly as in the paper's Llama3 case study (§5, §6.5).

    Sort (radix, scan-based) -> prefix-sum of sorted probabilities -> mask
    tokens whose *preceding* cumulative mass exceeds ``p`` -> renormalise ->
    weighted sample.  With fp16-style 16-bit keys this is the paper's "17 scans
    per batch row" operator; ``method="kernel"`` runs the sort as fused radix
    passes and the whole sampling tail as one Pallas launch.

    Args:
        logits: Unnormalised scores ``(..., vocab)``; softmax is applied in
            fp32.
        key: JAX PRNG key.
        p: Nucleus mass threshold in ``(0, 1]``.
        temperature: Logit divisor applied before the softmax.
        method: One of ``METHODS`` for the sort and sampling scans.
        sort_method: ``"radix"`` (scan-based, on bf16-rounded keys = 16 splits
            as in the paper's fp16 evaluation) or ``"xla"`` (baseline
            ``argsort``).
        tile_s: Tile side ``s`` for the mask scans.
        interpret: Force Pallas interpret mode.

    Returns:
        Sampled token ids, shape ``logits.shape[:-1]``, int32.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> logits = jnp.asarray([[0.0, 20.0, 0.0, 0.0]])
        >>> int(top_p_sample(logits, jax.random.PRNGKey(1), p=0.9)[0])
        1
    """
    if temperature != 1.0:
        logits = logits / temperature
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if sort_method == "radix":
        # Sort on bf16-rounded keys (16 bits = 16 splits, as in the paper's fp16
        # evaluation); ties/rounding only reorder within ~3-ulp probability bands.
        keys16 = probs.astype(jnp.bfloat16)
        _, order = radix_sort(keys16, descending=True, method=method,
                              tile_s=tile_s, interpret=interpret)
    else:
        order = jnp.argsort(-probs, axis=-1)
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    j = dispatch("top_p_tail", method)(
        sorted_p, key, p=p, method=method, tile_s=tile_s, interpret=interpret)
    return jnp.take_along_axis(order, j[..., None], axis=-1)[..., 0]
