"""Distributed operator family — paper §4's two-level algorithm for every op.

`repro.core.distributed` applies the paper's multi-core MCScan (Alg. 3) to
prefix sums: per-device partial results + one small collective carrying the
per-block summaries + a local fix-up.  This module generalizes that *same*
three-phase structure to the rest of the operator family, so the whole stack
(sort, top-k, nucleus sampling, linear recurrences, segmented scans) runs with
the scanned/sorted axis sharded over a mesh axis:

* **distributed radix sort** (:func:`dist_radix_sort`): each pass runs the
  per-shard radix-2^k multi-way split locally (phase 1), ``all_gather`` s the
  tiny per-shard bucket histograms and turns them into global bucket bases via
  an exclusive scan — the paper's phase-2 carry scan generalized to per-shard
  bases — then routes every element to its globally sorted slot with exactly
  **one** ``all_to_all`` bucket exchange per pass (phase 3).
* **sharded-vocab top-p sampling** (:func:`dist_top_p_sample`): softmax over
  the model-parallel vocab shard (``pmax``/``psum``), the distributed sort
  above on bf16 keys, per-shard sorted prefix mass via
  :func:`~repro.core.distributed.mcscan_local`, and a B-sized ``all_gather``
  of shard thresholds + ``psum`` rank count for the inverse-transform sample.
* **multi-device linear recurrence** (:func:`dist_linear_scan`): each shard is
  an affine map ``x -> A·x + B``; the ``(A, B)`` pairs travel in one small
  ``all_gather`` (phase 2) and fold into per-shard carries.
* **multi-device segmented scan** (:func:`dist_segment_scan`): the carry pair
  is (trailing segment sum, has-internal-boundary); the boundary flag zeroes
  the affine slope so carries stop at the first boundary of each shard.

Parity contract: every operator here is **bit-identical** to its single-device
sibling in :mod:`repro.core.primitives` / :mod:`~repro.core.linrec` /
:mod:`~repro.core.segmented` applied to the gathered input — for every
``method`` — except the floating-point sampling path of
:func:`dist_top_p_sample`, where the sharded softmax/prefix-mass reductions
associate differently and parity is documented-ulp (see
``docs/distributed.md``).  On a 1-device mesh every entry point short-circuits
to its local sibling, so the contract is trivially exact there.

Traffic contract: per-op closed forms for the collective bytes are derived in
``docs/distributed.md`` and checked against the HLO-lowered collectives
(``repro.analysis.roofline.parse_collectives``) by ``benchmarks/run.py dist``.

Doctests run on up to two host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=2``); by the parity
contract their outputs are identical on a 1-device mesh.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import guards
from repro.core.autotune import maybe_resolve
from repro.core.distributed import mcscan_local
from repro.core.linrec import cumprod, linear_scan, linrec_accum_dtype_for
from repro.core.primitives import (
    _encode_for_sort,
    _multi_split_dest,
    _reject_poisoned_logits,
    _scatter_payloads,
    _take_along_last,
    radix_sort,
    top_p_sample,
)
from repro.core.segmented import segment_scan
from repro.utils.compat import axis_size, shard_map, shard_map_unchecked

__all__ = [
    "dist_radix_sort", "dist_sort", "dist_topk", "dist_top_p_sample",
    "dist_linear_scan", "dist_segment_scan",
]


# ---------------------------------------------------------------------------
# shared shard_map plumbing
# ---------------------------------------------------------------------------


def _mesh_axis_size(mesh: Mesh, axis_name: str, *, op: str) -> int:
    """Validate that ``mesh`` has ``axis_name`` and return its size."""
    if not isinstance(mesh, Mesh):
        raise TypeError(f"{op}: mesh must be a jax.sharding.Mesh, got "
                        f"{type(mesh).__name__}")
    if axis_name not in mesh.shape:
        raise ValueError(f"{op}: mesh has no axis {axis_name!r}; available "
                         f"axes: {tuple(mesh.shape)}")
    return mesh.shape[axis_name]


def _sharded_spec(ndim: int, axis_name: str) -> P:
    """Last-axis-sharded ``PartitionSpec`` for an ``ndim``-dim array."""
    return P(*([None] * (ndim - 1) + [axis_name]))


def _shard_mapper(method: str):
    """Checked ``shard_map`` for pure-XLA methods, unchecked for Pallas ones.

    ``pallas_call`` has no replication rule, so the Pallas-launching methods
    (``kernel``/``blocked``) need the replication check disabled — the same
    rule :func:`repro.core.distributed.mcscan` applies.
    """
    return shard_map_unchecked if method in ("kernel", "blocked") else shard_map


def _pad_last(x: jax.Array, multiple: int, fill) -> Tuple[jax.Array, int]:
    """Pad the last axis of ``x`` up to a multiple; returns ``(padded, pad)``."""
    n = x.shape[-1]
    pad = (-n) % multiple
    if pad:
        fill_arr = jnp.full(x.shape[:-1] + (pad,), fill, x.dtype)
        x = jnp.concatenate([x, fill_arr], axis=-1)
    return x, pad


# ---------------------------------------------------------------------------
# the bucket exchange (phase 3 of the distributed radix pass)
# ---------------------------------------------------------------------------


def _exchange(channels: Sequence[jax.Array], gdest: jax.Array,
              axis_name: str) -> Tuple[jax.Array, ...]:
    """Route payload channels to their global slots with one ``all_to_all``.

    Every locally bucket-grouped element carries a unique global destination
    ``gdest`` in ``[0, D * n_local)``; destination shard is ``gdest //
    n_local`` and in-shard offset ``gdest % n_local``.  XLA's ``all_to_all``
    is static-shape, so the routing is materialized as a dense per-destination
    buffer ``(..., D, C, n_local)``: each source shard scatters its elements
    into the slots they own and leaves the additive identity everywhere else.
    Exactly one source shard populates any global slot, so after the exchange
    a sum over the source axis acts as a select — no second collective and no
    dynamic shapes.  The channels are bitcast to a common uint32 so ``C``
    payloads ride a single ``all_to_all`` (the per-pass collective-count
    contract: one ``all_gather`` + one ``all_to_all``).

    Args:
        channels: Arrays ``(..., n_local)`` of uint32/int32/float32 — 32-bit
            dtypes only (keys are widened before the pass loop).
        gdest: int32 global destination index per element, ``(..., n_local)``.
        axis_name: Mesh axis the sorted dimension is sharded over.

    Returns:
        The rerouted channels, same shapes and dtypes, each shard holding
        global slots ``[me * n_local, (me + 1) * n_local)``.
    """
    D = axis_size(axis_name)
    n_local = gdest.shape[-1]
    dtypes = [c.dtype for c in channels]
    packed = [c if c.dtype == jnp.uint32
              else jax.lax.bitcast_convert_type(c, jnp.uint32)
              for c in channels]
    C = len(packed)
    stacked = jnp.stack(packed, axis=-2)             # (..., C, n_local)
    shard = (gdest // n_local).astype(jnp.int32)
    offset = (gdest % n_local).astype(jnp.int32)

    def route_row(vals, s1, o1):
        """Scatter one row's channels into its dense (D, C, n_local) buffer."""
        ci = jnp.arange(C, dtype=jnp.int32)
        buf = jnp.zeros((D, C, n_local), jnp.uint32)
        return buf.at[s1[None, :], ci[:, None], o1[None, :]].set(vals)

    batch = gdest.shape[:-1]
    if batch:
        buf = jax.vmap(route_row)(stacked.reshape(-1, C, n_local),
                                  shard.reshape(-1, n_local),
                                  offset.reshape(-1, n_local))
        buf = buf.reshape(*batch, D, C, n_local)
    else:
        buf = route_row(stacked, shard, offset)
    ax = buf.ndim - 3                                # the destination-shard axis
    ex = jax.lax.all_to_all(buf, axis_name, split_axis=ax, concat_axis=ax)
    merged = jnp.sum(ex, axis=ax)                    # select: one writer per slot
    outs = []
    for c in range(C):
        v = merged[..., c, :]
        outs.append(v if dtypes[c] == jnp.uint32
                    else jax.lax.bitcast_convert_type(v, dtypes[c]))
    return tuple(outs)


def _global_dest(bucket: jax.Array, counts: jax.Array,
                 axis_name: str) -> jax.Array:
    """Global sorted slot of each locally bucket-grouped element.

    The paper's phase-2 carry scan generalized to per-shard bases: one
    ``all_gather`` of the tiny ``(..., R)`` per-shard histograms, an exclusive
    scan of the global bucket totals for the bucket bases, and a mask-matvec
    (exactly :func:`~repro.core.distributed.mcscan_local`'s ``before @ r``
    trick) for this shard's offset within each bucket.

    Args:
        bucket: int32 bucket id per locally *grouped* element, ``(...,
            n_local)`` — elements with the same id are contiguous.
        counts: int32 local histogram ``(..., R)``.
        axis_name: Mesh axis of the shards.

    Returns:
        int32 global destination index per element, ``(..., n_local)``;
        globally a permutation of ``0 .. D * n_local - 1``.
    """
    D = axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    c_all = jax.lax.all_gather(counts, axis_name)        # (D, ..., R)
    totals = jnp.sum(c_all, axis=0)                      # (..., R) global counts
    gbase = jnp.cumsum(totals, axis=-1) - totals         # global bucket bases
    before = (jnp.arange(D) < me).astype(jnp.int32)
    shard_off = jnp.tensordot(before, c_all, axes=(0, 0))  # earlier shards' share
    lbase = jnp.cumsum(counts, axis=-1) - counts         # local grouped bases
    iota = jnp.arange(bucket.shape[-1], dtype=jnp.int32)
    rank = iota - _take_along_last(lbase, bucket)        # within-bucket rank
    return _take_along_last(gbase + shard_off, bucket) + rank


def _local_group(channels: Tuple[jax.Array, ...], digits: jax.Array, radix: int,
                 *, shift: int, pass_bits: int, method: str, tile_s: int,
                 interpret: Optional[bool]):
    """Stable local radix-2^k grouping of the pass channels, with histogram.

    ``method="kernel"`` runs the (keys, perm) channels through the fused
    ``radix_pass_kernel`` with its per-shard histogram export (the
    ``with_counts=True`` path added for this layer) and any extra payload
    channel through ``multi_split_kernel``; the unfused methods share one
    :func:`~repro.core.primitives._multi_split_dest` mask scan for all
    channels, exactly like the single-device sort pass.

    Returns:
        ``(grouped_channels, counts)`` with ``counts`` int32 ``(..., R)``.
    """
    if method == "kernel":
        from repro.kernels import ops as _kops
        work, perm = channels[0], channels[1]
        *lead, n = work.shape
        w2 = work.reshape(-1, n)
        p2 = perm.reshape(-1, n)
        pad = (-n) % tile_s
        if pad:
            fill = jnp.full((w2.shape[0], pad), jnp.iinfo(work.dtype).max,
                            work.dtype)
            w2 = jnp.concatenate([w2, fill], axis=-1)
            p2 = jnp.concatenate([p2, jnp.zeros((p2.shape[0], pad), p2.dtype)],
                                 axis=-1)
        wo, po, cnt = _kops.radix_pass_kernel(
            w2, p2, shift=shift, pass_bits=pass_bits, s=tile_s,
            interpret=interpret, with_counts=True)
        # padding keys are all-ones, so they land in (and are removed from)
        # the top bucket; grouped pads sit at the end and slice away
        cnt = cnt.at[:, radix - 1].add(-pad)
        grouped = [wo[:, :n].reshape(*lead, n), po[:, :n].reshape(*lead, n)]
        for extra in channels[2:]:
            e2 = extra.reshape(-1, n)
            if pad:
                e2 = jnp.concatenate(
                    [e2, jnp.zeros((e2.shape[0], pad), e2.dtype)], axis=-1)
            d2 = ((w2 >> shift) & jnp.asarray(radix - 1, w2.dtype)
                  ).astype(jnp.int32)
            z, _, _ = _kops.multi_split_kernel(e2, d2, num_buckets=radix,
                                               s=tile_s, interpret=interpret)
            grouped.append(z[:, :n].reshape(*lead, n))
        return tuple(grouped), cnt.reshape(*lead, radix)
    dest, counts = _multi_split_dest(digits, radix, method=method,
                                     tile_s=tile_s)
    grouped = _scatter_payloads(tuple(channels), dest, with_indices=False)
    return grouped, counts


def _dist_radix_passes(channels: Tuple[jax.Array, ...], bits: int,
                       axis_name: str, *, method: str, tile_s: int,
                       bits_per_pass: int, interpret: Optional[bool]):
    """Run all distributed radix passes; ``channels[0]`` holds the work keys.

    Per pass: local stable multi-way split (phase 1), histogram
    ``all_gather`` + global bucket bases (phase 2), one ``all_to_all`` bucket
    exchange (phase 3).  Keys must already be widened to uint32 (only the low
    ``bits`` are inspected) and any descending complement applied.
    """
    for shift in range(0, bits, bits_per_pass):
        k = min(bits_per_pass, bits - shift)
        radix = 1 << k
        work = channels[0]
        mask = jnp.asarray(radix - 1, work.dtype)
        digits = ((work >> shift) & mask).astype(jnp.int32)
        grouped, counts = _local_group(channels, digits, radix, shift=shift,
                                       pass_bits=k, method=method,
                                       tile_s=tile_s, interpret=interpret)
        bucket = ((grouped[0] >> shift) & mask).astype(jnp.int32)
        gdest = _global_dest(bucket, counts, axis_name)
        channels = _exchange(grouped, gdest, axis_name)
    return channels


# ---------------------------------------------------------------------------
# distributed sort / top-k
# ---------------------------------------------------------------------------


def dist_radix_sort(x: jax.Array, mesh: Mesh, axis_name: str = "data", *,
                    descending: bool = False, method: str = "auto",
                    return_indices: bool = True, tile_s: int = 128,
                    bits_per_pass: int = 4, interpret: Optional[bool] = None):
    """Stable LSB radix sort with the keys sharded over a mesh axis.

    The paper's scan-based radix sort (§5) lifted to the two-level §4
    structure: each of the ``ceil(bits / bits_per_pass)`` passes runs the
    per-shard multi-way split locally, ``all_gather`` s the ``(D, R)`` bucket
    histograms, derives global bucket bases with an exclusive scan (the
    phase-2 carry scan over per-shard bases), and redistributes (key, index)
    pairs with exactly one ``all_to_all``.  Bit-identical to
    :func:`repro.core.primitives.radix_sort` on the gathered input for every
    ``method`` — bucket offsets are exact integer mask scans and the
    shard-major exchange order preserves stability.

    Args:
        x: Global keys ``(..., n)`` (dtypes as in ``radix_sort``); ``n`` need
            not divide the axis size — the tail is padded with the maximum
            key internally and sliced off.
        mesh: Device mesh; the last axis of ``x`` is sharded over it.
        axis_name: Mesh axis to shard the sorted axis over.  A size-1 axis
            short-circuits to the single-device sort (no collectives).
        descending: Sort high-to-low (stability preserved by complementing
            the encoded keys, exactly as in the local sort).
        method: One of ``METHODS`` (``"auto"`` resolves on the per-shard
            length) for the local mask scans.
        return_indices: If false, return only the sorted values.
        tile_s: Tile side ``s`` for the local mask scans.
        bits_per_pass: Bits retired per radix pass (``1..8``).
        interpret: Force Pallas interpret mode.

    Returns:
        ``(values, permutation)`` — or just ``values`` — as *global* arrays,
        matching the single-device :func:`~repro.core.primitives.radix_sort`.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> from repro.utils.compat import make_mesh
        >>> mesh = make_mesh((min(2, jax.device_count()),), ("data",))
        >>> v, i = dist_radix_sort(jnp.asarray([3, -1, 2, -5], jnp.int8), mesh)
        >>> v.tolist(), i.tolist()
        ([-5, -1, 2, 3], [3, 1, 2, 0])
    """
    bits_per_pass = guards.validate_bits_per_pass(bits_per_pass,
                                                  op="dist_radix_sort")
    D = _mesh_axis_size(mesh, axis_name, op="dist_radix_sort")
    if D == 1:
        return radix_sort(x, descending=descending, method=method,
                          return_indices=return_indices, tile_s=tile_s,
                          bits_per_pass=bits_per_pass, interpret=interpret)
    n = x.shape[-1]
    enc, bits, decode = _encode_for_sort(x)
    if descending:
        enc = ~enc
    work = enc.astype(jnp.uint32)
    # pad to a D-divisible length with the maximum key: padding stays at the
    # global end of every pass (stability: real max-key ties precede it)
    work, _ = _pad_last(work, D, jnp.uint32(0xFFFFFFFF))
    n_pad = work.shape[-1]
    method = maybe_resolve(method, "dist_sort", n_pad // D, x.dtype)
    gperm = jnp.broadcast_to(jnp.arange(n_pad, dtype=jnp.int32), work.shape)

    def body(w, p):
        """Per-shard distributed radix passes (see ``_dist_radix_passes``)."""
        w, p = _dist_radix_passes(
            (w, p), bits, axis_name, method=method, tile_s=tile_s,
            bits_per_pass=min(bits_per_pass, bits), interpret=interpret)
        return w, p

    spec = _sharded_spec(work.ndim, axis_name)
    fn = _shard_mapper(method)(body, mesh=mesh, in_specs=(spec, spec),
                               out_specs=(spec, spec))
    work, gperm = fn(work, gperm)
    work = work[..., :n].astype(enc.dtype)
    gperm = gperm[..., :n]
    if descending:
        work = ~work
    values = decode(work)
    if return_indices:
        return values, gperm
    return values


def dist_sort(x: jax.Array, mesh: Mesh, axis_name: str = "data", *,
              descending: bool = False, method: str = "auto",
              tile_s: int = 128, bits_per_pass: int = 4,
              interpret: Optional[bool] = None):
    """PyTorch-style sharded ``sort``: ``(values, indices)`` over a mesh axis.

    Thin wrapper over :func:`dist_radix_sort`, mirroring
    :func:`repro.core.primitives.sort`.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> from repro.utils.compat import make_mesh
        >>> mesh = make_mesh((min(2, jax.device_count()),), ("data",))
        >>> v, i = dist_sort(jnp.asarray([2, 9, 4, 1], jnp.int8), mesh,
        ...                  descending=True)
        >>> v.tolist(), i.tolist()
        ([9, 4, 2, 1], [1, 2, 0, 3])
    """
    return dist_radix_sort(x, mesh, axis_name, descending=descending,
                           method=method, return_indices=True, tile_s=tile_s,
                           bits_per_pass=bits_per_pass, interpret=interpret)


def dist_topk(x: jax.Array, k: int, mesh: Mesh, axis_name: str = "data", *,
              method: str = "auto", tile_s: int = 128, bits_per_pass: int = 4,
              interpret: Optional[bool] = None):
    """Top-k of a sharded array via the distributed descending radix sort.

    Mirrors :func:`repro.core.primitives.topk`: the fully sorted global order
    is materialized (the paper's §5 formulation) and the leading ``k``
    columns sliced — XLA keeps only the slice's producing shards live.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> from jax.sharding import Mesh
        >>> from repro.utils.compat import make_mesh
        >>> mesh = make_mesh((min(2, jax.device_count()),), ("data",))
        >>> v, i = dist_topk(jnp.asarray([1, 9, 3, 7], jnp.int8), 2, mesh)
        >>> v.tolist(), i.tolist()
        ([9, 7], [1, 3])
    """
    values, idx = dist_radix_sort(x, mesh, axis_name, descending=True,
                                  method=method, tile_s=tile_s,
                                  bits_per_pass=bits_per_pass,
                                  interpret=interpret)
    return values[..., :k], idx[..., :k]


# ---------------------------------------------------------------------------
# the affine carry fold (phase 2 of linrec / segmented)
# ---------------------------------------------------------------------------


def _affine_carry(A: jax.Array, B: jax.Array, axis_name: str, s0) -> jax.Array:
    """Exclusive fold of per-shard affine maps — one small ``all_gather``.

    Shard ``d`` summarizes its chunk as ``x -> A_d * x + B_d``; the incoming
    carry of shard ``me`` is the composition of all earlier shards applied to
    ``s0``.  The ``(A, B)`` pairs are stacked so one ``all_gather`` of ``2B``
    scalars per batch row carries phase 2 (vs. ``2N`` local traffic), and the
    fold unrolls over the static axis size — the direct generalization of
    :func:`~repro.core.distributed.mcscan_local`'s masked matvec to affine
    carries.

    Args:
        A: Local slope ``(..., 1)`` (accumulation dtype).
        B: Local offset ``(..., 1)``, same shape/dtype.
        axis_name: Mesh axis of the shards.
        s0: Scalar initial carry.

    Returns:
        The incoming carry for this shard, shape ``(..., 1)``.
    """
    D = axis_size(axis_name)
    me = jax.lax.axis_index(axis_name)
    ab = jnp.concatenate([jnp.broadcast_to(A, B.shape), B], axis=-1)
    g = jax.lax.all_gather(ab, axis_name)            # (D, ..., 2) carry pairs
    s = jnp.zeros_like(B) + jnp.asarray(s0, B.dtype)
    for d in range(D):                               # static exclusive unroll
        s = jnp.where(d < me, g[d, ..., 0:1] * s + g[d, ..., 1:2], s)
    return s


# ---------------------------------------------------------------------------
# distributed linear recurrence
# ---------------------------------------------------------------------------


def dist_linear_scan(a: jax.Array, b: jax.Array, mesh: Mesh,
                     axis_name: str = "data", *, exclusive: bool = False,
                     initial=None, method: str = "auto",
                     precision: str = "highest", tile_s: int = 128,
                     block_tiles: int = 8, accum_dtype=None) -> jax.Array:
    """First-order linear recurrence with the scanned axis sharded.

    ``y_t = a_t * y_{t-1} + b_t`` on the §4 two-level structure: each shard
    runs the local :func:`repro.core.linrec.linear_scan` (phase 1, cube
    units) while its affine summary ``(A, B) = (prod a, trailing b-sum)`` is
    computed *independently* — ``B`` from reversed suffix products, not from
    the local scan's last element — so the ``all_gather`` of the ``2B`` carry
    pairs has no data dependency on the local scan and the scheduler overlaps
    them, exactly the paper's cube/vector phase-1 overlap.  Phase 3 applies
    the folded incoming carry through the local multiplier prefix.
    Bit-identical to the single-device sibling on gathered inputs for exact
    (integer) dtypes; for floats the carry association matches the local
    ``method``'s blocked association (documented-ulp).

    Args:
        a: Multipliers ``(..., n)``; broadcast against ``b``.
        b: Addends ``(..., n)``.
        mesh: Device mesh; last axis sharded over ``axis_name``.
        axis_name: Mesh axis; size 1 short-circuits to the local op.
        exclusive: Shift-by-one output, ``out[0] = initial``.
        initial: Scalar initial carry (``y_{-1}``); defaults to 0.
        method: One of ``METHODS`` for the local recurrence.
        precision: Matmul precision for the local recurrence.
        tile_s: Tile side ``s``.
        block_tiles: Tiles per block for ``method="blocked"``.
        accum_dtype: Accumulation dtype override; defaults to
            :func:`~repro.core.linrec.linrec_accum_dtype_for`.

    Returns:
        The recurrence output, same shape as the broadcast inputs, in the
        accumulation dtype.

    Raises:
        NotImplementedError: For ``reverse`` semantics — flip the inputs
            globally instead.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> from repro.utils.compat import make_mesh
        >>> mesh = make_mesh((min(2, jax.device_count()),), ("data",))
        >>> a = jnp.asarray([1., 2., 1., 3.]); b = jnp.asarray([1., 0., 5., 1.])
        >>> dist_linear_scan(a, b, mesh).tolist()
        [1.0, 2.0, 7.0, 22.0]
    """
    D = _mesh_axis_size(mesh, axis_name, op="dist_linear_scan")
    if D == 1:
        return linear_scan(a, b, exclusive=exclusive, initial=initial,
                           method=method, precision=precision, tile_s=tile_s,
                           block_tiles=block_tiles, accum_dtype=accum_dtype)
    shape = jnp.broadcast_shapes(a.shape, b.shape)
    a = jnp.broadcast_to(a, shape)
    b = jnp.broadcast_to(b, shape)
    n = shape[-1]
    a, _ = _pad_last(a, D, 1)                # identity tail: a=1, b=0
    b, _ = _pad_last(b, D, 0)
    acc = (jnp.dtype(accum_dtype) if accum_dtype is not None
           else linrec_accum_dtype_for(jnp.result_type(a, b)))
    method = maybe_resolve(method, "dist_linear_scan", a.shape[-1] // D,
                           jnp.result_type(a, b))
    s0 = 0 if initial is None else initial

    def body(al, bl):
        """Local recurrence + independent affine summary + carry fold."""
        y_loc = linear_scan(al, bl, exclusive=exclusive, method=method,
                            precision=precision, tile_s=tile_s,
                            block_tiles=block_tiles, accum_dtype=acc)
        p = cumprod(al, method=method, precision=precision, tile_s=tile_s,
                    block_tiles=block_tiles, accum_dtype=acc)
        A_loc = p[..., -1:]
        # phase-1 "vector units": B from reversed suffix products, independent
        # of y_loc, so the all_gather overlaps the local scan
        q = jnp.flip(jnp.cumprod(jnp.flip(al.astype(acc), -1), axis=-1), -1)
        q_excl = jnp.concatenate([q[..., 1:], jnp.ones_like(q[..., :1])], -1)
        B_loc = jnp.sum(bl.astype(acc) * q_excl, axis=-1, keepdims=True)
        s = _affine_carry(A_loc, B_loc, axis_name, s0)
        mult = (jnp.concatenate([jnp.ones_like(p[..., :1]), p[..., :-1]], -1)
                if exclusive else p)
        return y_loc + s * mult

    spec = _sharded_spec(a.ndim, axis_name)
    fn = _shard_mapper(method)(body, mesh=mesh, in_specs=(spec, spec),
                               out_specs=spec)
    return fn(a, b)[..., :n]


# ---------------------------------------------------------------------------
# distributed segmented scan
# ---------------------------------------------------------------------------


def dist_segment_scan(values: jax.Array, offsets: jax.Array, mesh: Mesh,
                      axis_name: str = "data", *, exclusive: bool = False,
                      method: str = "auto", tile_s: int = 128,
                      block_tiles: int = 8, accum_dtype=None,
                      precision: str = "highest") -> jax.Array:
    """Segmented prefix sum with the flattened value axis sharded.

    Each shard clips the global CSR ``offsets`` into its own window (always a
    valid local CSR) and runs the local
    :func:`repro.core.segmented.segment_scan` (phase 1).  The carry pair is
    the degenerate affine map ``(A, B)`` with ``A = [shard has no internal
    boundary]`` and ``B`` the shard's trailing inclusive sum — boundary
    shards zero the slope, so the folded carry (phase 2, one ``2B``-scalar
    ``all_gather``) is exactly the sum flowing into each shard's leading
    open segment; phase 3 adds it to positions before the first boundary.
    Bit-identical to the single-device sibling on gathered inputs (the int8
    -> int32 mask-scan exactness argument carries over unchanged).

    Args:
        values: Global flattened values ``(..., n)``.
        offsets: CSR segment starts ``(num_segments + 1,)`` int32 with
            ``offsets[0] == 0`` and ``offsets[-1] == n``, shared by all batch
            rows (replicated to every shard).
        mesh: Device mesh; last axis of ``values`` sharded over ``axis_name``.
        axis_name: Mesh axis; size 1 short-circuits to the local op.
        exclusive: Per-segment exclusive scan.
        method: One of ``METHODS`` for the local segmented scan.
        tile_s: Tile side ``s``.
        block_tiles: Tiles per block for ``method="blocked"``.
        accum_dtype: Accumulation dtype override (int8 masks still accumulate
            in int32 by default).
        precision: Matmul precision for the local scans.

    Returns:
        The per-segment scan, same shape as ``values``, accumulation dtype.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> from repro.utils.compat import make_mesh
        >>> mesh = make_mesh((min(2, jax.device_count()),), ("data",))
        >>> out = dist_segment_scan(jnp.ones((4,), jnp.int8),
        ...                         jnp.asarray([0, 3, 4], jnp.int32), mesh)
        >>> out.tolist()
        [1, 2, 3, 1]
    """
    offsets = guards.validate_offsets(offsets, values.shape[-1],
                                      op="dist_segment_scan")
    D = _mesh_axis_size(mesh, axis_name, op="dist_segment_scan")
    if D == 1:
        return segment_scan(values, offsets, exclusive=exclusive,
                            method=method, tile_s=tile_s,
                            block_tiles=block_tiles, accum_dtype=accum_dtype,
                            precision=precision)
    n = values.shape[-1]
    values, pad = _pad_last(values, D, 0)
    n_pad = values.shape[-1]
    if pad:
        # extend the final segment over the zero tail (prefixes at real
        # positions are unchanged; the tail is sliced off)
        offsets = offsets.at[-1].set(n_pad)
    n_local = n_pad // D
    method = maybe_resolve(method, "dist_segment_scan", n_local, values.dtype)

    def body(xl, offs):
        """Local clipped-CSR scan + boundary-gated carry fold."""
        me = jax.lax.axis_index(axis_name)
        start = me * n_local
        off_loc = jnp.clip(offs - start, 0, n_local)
        y_loc = segment_scan(xl, off_loc, exclusive=exclusive, method=method,
                             tile_s=tile_s, block_tiles=block_tiles,
                             accum_dtype=accum_dtype, precision=precision)
        acc = y_loc.dtype
        pos = offs[:-1] - start                       # segment starts, local
        internal = (pos >= 0) & (pos < n_local)
        first = jnp.min(jnp.where(internal, pos, n_local))
        A_loc = jnp.broadcast_to((first == n_local).astype(acc),
                                 y_loc.shape[:-1] + (1,))
        tail = (y_loc[..., -1:] + xl[..., -1:].astype(acc) if exclusive
                else y_loc[..., -1:])                 # trailing inclusive sum
        s = _affine_carry(A_loc, tail, axis_name, 0)
        gate = (jnp.arange(n_local) < first).astype(acc)
        return y_loc + s * gate

    spec = _sharded_spec(values.ndim, axis_name)
    fn = _shard_mapper(method)(body, mesh=mesh, in_specs=(spec, P(None)),
                               out_specs=spec)
    return fn(values, offsets)[..., :n]


# ---------------------------------------------------------------------------
# sharded-vocab nucleus sampling
# ---------------------------------------------------------------------------


def dist_top_p_sample(logits: jax.Array, key, mesh: Mesh,
                      axis_name: str = "model", p: float = 0.9,
                      temperature: float = 1.0, *, method: str = "auto",
                      tile_s: int = 128, bits_per_pass: int = 4,
                      u: Optional[jax.Array] = None,
                      interpret: Optional[bool] = None,
                      nonfinite: str = "propagate") -> jax.Array:
    """Nucleus sampling with the vocabulary axis model-parallel.

    The paper's Llama3 sampling pipeline (§5/§6.5) without gathering the
    vocab: softmax normalizers travel as two scalar collectives
    (``pmax``/``psum``), the bf16 sort keys + token ids + fp32 probabilities
    ride the distributed radix sort's per-pass ``all_to_all`` as packed
    uint32 channels, the sorted prefix mass is per-shard
    :func:`~repro.core.distributed.mcscan_local` scans, and the
    inverse-transform index is a B-sized ``all_gather`` of shard thresholds
    (the total nucleus mass is the last shard's CDF tail) plus a ``psum``
    rank count and a ``psum`` one-shard token gather.

    Parity: the sort itself is bit-exact integer routing, but the sharded
    softmax denominator and the two-level prefix mass associate differently
    from the single-device sibling, so token parity is **documented-ulp**
    (`docs/distributed.md`) rather than bitwise: a draw lands on a different
    token only when ``u`` falls within a few ulp of a nucleus CDF boundary.

    Args:
        logits: Global unnormalized scores ``(..., vocab)``; the last axis
            is sharded over ``axis_name`` (non-divisible vocab is padded
            with ``-inf`` internally).
        key: JAX PRNG key (unused when ``u`` is given).
        mesh: Device mesh.
        axis_name: Mesh axis of the vocab shards (``"model"`` matches
            ``repro.utils.sharding``'s Megatron-style rules); size 1
            short-circuits to :func:`repro.core.primitives.top_p_sample`.
        p: Nucleus mass threshold in ``(0, 1]``.
        temperature: Logit divisor; ``0`` is the documented greedy limit.
        method: One of ``METHODS`` for the sort and prefix-mass scans.
        tile_s: Tile side ``s``.
        bits_per_pass: Bits retired per radix pass over the 16 bf16 key bits.
        u: Optional pre-drawn uniforms ``logits.shape[:-1] + (1,)``
            overriding the ``key`` draw (deterministic replay; the serving
            engines' batched wiring uses this).
        interpret: Force Pallas interpret mode.
        nonfinite: Non-finite logit policy (dispatch rule 10) with the same
            three behaviours as the single-device sampler.

    Returns:
        Sampled token ids, shape ``logits.shape[:-1]``, int32.

    Example:
        >>> import jax, jax.numpy as jnp
        >>> from repro.utils.compat import make_mesh
        >>> mesh = make_mesh((min(2, jax.device_count()),), ("model",))
        >>> logits = jnp.asarray([[0.0, 20.0, 0.0, 0.0]])
        >>> int(dist_top_p_sample(logits, jax.random.PRNGKey(1), mesh, p=0.9)[0])
        1
    """
    guards.validate_probability(p, op="dist_top_p_sample")
    guards.validate_temperature(temperature, op="dist_top_p_sample")
    bits_per_pass = guards.validate_bits_per_pass(bits_per_pass,
                                                  op="dist_top_p_sample")
    nonfinite = guards.resolve_nonfinite(nonfinite)
    D = _mesh_axis_size(mesh, axis_name, op="dist_top_p_sample")
    if D == 1:
        return top_p_sample(logits, key, p=p, temperature=temperature,
                            method=method, sort_method="radix", tile_s=tile_s,
                            bits_per_pass=bits_per_pass, u=u,
                            interpret=interpret, nonfinite=nonfinite)
    if guards.is_concrete(temperature) and float(temperature) == 0.0:
        greedy = jnp.where(jnp.isnan(logits), -jnp.inf, logits)
        return jnp.argmax(greedy, axis=-1).astype(jnp.int32)
    if nonfinite == "raise":
        logits = _reject_poisoned_logits(logits)
    if temperature != 1.0:
        logits = logits / temperature
    n = logits.shape[-1]
    # -inf padding: zero probability, exact softmax denominator, sorts last
    logits, _ = _pad_last(logits.astype(jnp.float32), D, -jnp.inf)
    n_local = logits.shape[-1] // D
    method = maybe_resolve(method, "dist_top_p_sample", n_local, jnp.float32)
    if u is None:
        u = jax.random.uniform(key, logits.shape[:-1] + (1,),
                               dtype=jnp.float32)
    if nonfinite == "sanitize":
        bad = ~(jnp.any(jnp.isfinite(logits), axis=-1)
                & ~jnp.any(jnp.isnan(logits), axis=-1))
        greedy = jnp.argmax(jnp.where(jnp.isnan(logits), -jnp.inf, logits),
                            axis=-1).astype(jnp.int32)
    else:
        bad = jnp.zeros(logits.shape[:-1], bool)
        greedy = jnp.zeros(logits.shape[:-1], jnp.int32)

    def body(ll, uu, bb, gg):
        """Sharded softmax -> distributed sort -> local prefix mass -> sample."""
        me = jax.lax.axis_index(axis_name)
        start = me * n_local
        gidx = start + jnp.arange(n_local, dtype=jnp.int32)
        m = jax.lax.pmax(jnp.max(ll, axis=-1, keepdims=True), axis_name)
        e = jnp.exp(ll - m)
        denom = jax.lax.psum(jnp.sum(e, axis=-1, keepdims=True), axis_name)
        probs = e / denom
        if nonfinite == "sanitize":
            onehot = (gidx == gg[..., None]).astype(probs.dtype)
            probs = jnp.where(bb[..., None], onehot, probs)
        # 16 bf16 sort bits as in the paper's fp16 evaluation; descending
        keys16, _, _ = _encode_for_sort(probs.astype(jnp.bfloat16))
        work = (~keys16).astype(jnp.uint32)
        toks = jnp.broadcast_to(gidx, probs.shape)
        _, tok_sorted, p_sorted = _dist_radix_passes(
            (work, toks, probs), 16, axis_name, method=method, tile_s=tile_s,
            bits_per_pass=bits_per_pass, interpret=interpret)
        cum = mcscan_local(p_sorted, axis_name, method=method, tile_s=tile_s)
        cut = (cum - p_sorted) > p                 # llama3's sample_top_p cut
        masked = jnp.where(cut, 0.0, p_sorted)
        cdf = mcscan_local(masked, axis_name, method=method, tile_s=tile_s)
        # B-sized all_gather of shard thresholds: the global nucleus mass is
        # the last shard's CDF tail; earlier tails are free diagnostics
        tails = jax.lax.all_gather(cdf[..., -1:], axis_name)
        total = tails[-1]
        theta = uu.astype(cdf.dtype) * total
        rank = jax.lax.psum(jnp.sum((cdf < theta).astype(jnp.int32), axis=-1),
                            axis_name)
        rank = jnp.clip(rank, 0, n - 1)       # pads carry zero mass: never hit
        rel = rank - start
        in_range = (rel >= 0) & (rel < n_local)
        at = _take_along_last(tok_sorted,
                              jnp.clip(rel, 0, n_local - 1)[..., None])[..., 0]
        tok = jax.lax.psum(jnp.where(in_range, at, 0), axis_name)
        return tok, total

    spec = _sharded_spec(logits.ndim, axis_name)
    rep_full = P(*([None] * logits.ndim))
    rep_lead = P(*([None] * (logits.ndim - 1)))
    # unchecked: tok/total are replicated through psum/all_gather, but the
    # bucket-exchange all_to_all in between defeats static replication
    # inference (see utils/compat.py on the warn path)
    fn = shard_map_unchecked(
        body, mesh=mesh, in_specs=(spec, rep_full, rep_lead, rep_lead),
        out_specs=(rep_lead, rep_full))
    tok, total = fn(logits, u, bad, greedy)
    guards.guard_check(lambda: jnp.all(jnp.isfinite(total)),
                       "dist_top_p_sample: non-finite nucleus mass before "
                       "the inverse-transform sample")
    if nonfinite == "sanitize":
        tok = jnp.where(bad, greedy, tok)
    return tok
