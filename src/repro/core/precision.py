"""Precision policy for the matmul-engine scans (``precision="compensated"``).

The paper's cube unit earns its bandwidth by contracting in fp16/bf16, yet the
repo's matmul scan paths contract in fp32 (ROADMAP item 3).  This module adds
the missing axis — one shared split/accumulate helper reused by every
scan-triangle contraction (*SIMD²*'s "factor the engine trick once" argument),
implementing the Ozaki/Ootomo error-compensated split of *SGEMM-cube* (see
PAPERS.md):

* ``"highest"`` (default) — today's behaviour: operands feed the engine in
  fp32 (or their native dtype) and accumulate per ``preferred_element_type``.
* ``"compensated"`` — fp32 data operands split **exactly** into a fp16 high
  part plus a ``2^-11``-scaled fp16 low part after an exact per-slice
  power-of-two scaling; the cross terms contract on the fp16 engine with fp32
  accumulation and recombine to ~22 significand bits, matching the fp32
  ``"vector"`` path within the documented ulp bound
  (:mod:`repro.analysis.ulp`).  The ``lo×lo`` term (< 2^-22 relative) is
  dropped.
* ``"fast"`` — plain bf16 engine feed with fp32 accumulation; ~8 significand
  bits, loose bound, maximum throughput.

Resolution is static (pre-trace), mirroring ``method="auto"`` dispatch
(:mod:`repro.core.autotune`): an active :func:`precision_override` context
wins, else the ``REPRO_SCAN_PRECISION`` environment variable, else the
call-site argument — ``docs/architecture.md`` dispatch rule 9.  Two
interactions are fixed by :func:`resolve_precision`:

* an **explicit** ``method="vector"`` with an explicit non-default
  ``precision`` raises ``ValueError`` — the vector path never touches the
  matrix engine, so the request is unsatisfiable;
* when ``method="auto"`` (or an override/env var) lands on ``"vector"``, the
  precision silently degrades to ``"highest"`` — the vector path *is* the
  fp32 reference the compensated contract is stated against.

Only fp32 data operands are ever split: integer/bool contractions stay exact
and sub-fp32 float inputs (bf16/f16) already feed the engine natively, so for
those every precision is identical to ``"highest"`` by construction.

The exact power-of-two scaling here (``frexp``/``ldexp`` — no rounding) is the
same trick :func:`repro.core.linrec._pair_w` uses to keep windowed cumulative
products in range; its mantissa normalization lives here
(:func:`normalize_exponents`) so both users share one exponent-handling
implementation.
"""
from __future__ import annotations

import contextlib
import os
from typing import List, Optional

import jax.numpy as jnp

__all__ = [
    "PRECISIONS", "SPLIT_SHIFT", "ENV_VAR",
    "resolve_precision", "precision_override",
    "split_f16", "normalize_exponents", "pdot",
]

PRECISIONS = ("highest", "compensated", "fast")
ENV_VAR = "REPRO_SCAN_PRECISION"

# fp16 carries 11 significand bits (incl. the implicit one): the low split
# part is pre-scaled by 2^SPLIT_SHIFT so its leading bits are exactly the
# residual bits the high part dropped.
SPLIT_SHIFT = 11

# Mantissas are normalized into [√½, √2) (not frexp's [½, 1)) so products and
# quotients of normalized values stay within one octave of 1 — shared with the
# linrec weighted-triangle construction (see module docstring).
_SQRT_HALF = 0.7071067811865476

_OVERRIDE: List[str] = []


@contextlib.contextmanager
def precision_override(precision: str):
    """Force every precision resolution to ``precision`` inside the block.

    The in-process analogue of the ``REPRO_SCAN_PRECISION`` environment
    variable (and it takes precedence over it) — the precision counterpart of
    :func:`repro.core.autotune.method_override`.  An override landing on a
    ``"vector"``-dispatched call degrades to ``"highest"`` silently (the
    vector path is the fp32 reference), and never affects integer/bool
    contractions (those stay exact by construction).

    Args:
        precision: One of ``PRECISIONS``.

    Raises:
        ValueError: If ``precision`` is not a known precision.

    Example:
        >>> with precision_override("compensated"):
        ...     resolve_precision("highest", method="matmul")
        'compensated'
    """
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; expected one of "
                         f"{PRECISIONS}")
    _OVERRIDE.append(precision)
    try:
        yield
    finally:
        _OVERRIDE.pop()


def _env_precision() -> Optional[str]:
    """The ``REPRO_SCAN_PRECISION`` forced precision, or ``None``."""
    p = os.environ.get(ENV_VAR)
    if not p:
        return None
    if p not in PRECISIONS:
        raise ValueError(f"{ENV_VAR}={p!r} is not a known precision; expected "
                         f"one of {PRECISIONS}")
    return p


def resolve_precision(precision: str = "highest", *, method: Optional[str] = None,
                      explicit_method: bool = True) -> str:
    """Resolve the effective precision for one operator call (pre-trace).

    Resolution order (``docs/architecture.md`` dispatch rule 9): an active
    :func:`precision_override` context wins, else ``REPRO_SCAN_PRECISION``,
    else the call-site ``precision`` argument.  Like ``method`` resolution
    this happens in Python before tracing, so the jaxpr of a call is
    identical to passing the resolved precision explicitly.

    Args:
        precision: The caller-supplied ``precision=`` argument.
        method: The **resolved** concrete method of the call (never
            ``"auto"``), used for the vector-path rules below; ``None`` skips
            them.
        explicit_method: Whether the caller named the method explicitly
            (``False`` when ``method="auto"`` resolution picked it).

    Returns:
        One of ``PRECISIONS``.

    Raises:
        ValueError: If ``precision`` (argument or environment) is unknown, or
            if an explicitly requested non-default precision is combined with
            an explicit ``method="vector"`` — the vector path never touches
            the matrix engine, so the request cannot be honoured.

    Example:
        >>> resolve_precision("compensated", method="kernel")
        'compensated'
        >>> resolve_precision("compensated", method="vector",
        ...                   explicit_method=False)  # auto picked vector
        'highest'
        >>> try:
        ...     resolve_precision("fast", method="vector")
        ... except ValueError:
        ...     print("rejected")
        rejected
    """
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; expected one of "
                         f"{PRECISIONS}")
    if method == "vector" and explicit_method and precision != "highest":
        raise ValueError(
            f"precision={precision!r} requires a matmul-engine method "
            "('matmul', 'kernel' or 'blocked'); method='vector' never touches "
            "the matrix engine.  Drop precision= (the vector path is the fp32 "
            "reference) or pick an engine method / method='auto'.")
    p = _OVERRIDE[-1] if _OVERRIDE else None
    if p is None:
        p = _env_precision()
    if p is None:
        p = precision
    if method == "vector" and p != "highest":
        # auto/override/env landed on the fp32 reference path: degrade.
        return "highest"
    return p


# ---------------------------------------------------------------------------
# Exact exponent handling (shared with the linrec weighted triangle)
# ---------------------------------------------------------------------------


def normalize_exponents(a, acc):
    """Split ``a`` exactly into mantissas in ``[√½, √2)`` and int32 exponents.

    ``a == a_norm · 2^e`` with no rounding (``frexp`` and the conditional
    doubling are power-of-two moves).  Centering mantissas on 1 (geometric
    mean of the interval endpoints) keeps window products of ``n`` of them
    within ``2^±(n/2)`` — the bound :func:`repro.core.linrec._pair_w` relies
    on for its tile-bounded cumulative products, and the reason the fp16
    split's per-slice scaling never overflows the half-precision range.

    Args:
        a: Float array (zeros map to ``(0, 0)`` like ``frexp``).
        acc: Dtype the mantissas are produced in.

    Returns:
        ``(a_norm, e)`` — mantissas in ``acc`` and int32 exponents.

    Example:
        >>> import jax.numpy as jnp
        >>> m, e = normalize_exponents(jnp.asarray([0.25, 3.0]), jnp.float32)
        >>> [float(v) for v in m], [int(v) for v in e]
        ([1.0, 0.75], [-2, 2])
    """
    m, e = jnp.frexp(a.astype(acc))                     # a = m·2^e, |m| ∈ [½,1)
    small = jnp.abs(m) < _SQRT_HALF
    a_norm = jnp.where(small, m * 2, m).astype(acc)
    es = jnp.where(small, e - 1, e).astype(jnp.int32)
    return a_norm, es


def split_f16(x, axis: int):
    """Exact per-slice scaled Ozaki split of fp32 ``x`` into fp16 high/low parts.

    Slices along ``axis`` (the contraction axis of the matmul the parts feed)
    are scaled by an exact power of two so their largest finite magnitude
    lands in ``[½, 1)`` — inside fp16's range whatever the fp32 exponents
    were (subnormal rows scale *up*, near-overflow rows scale *down*).  The
    high part is the fp16 rounding of the scaled slice; the residual
    (exact in fp32 by Sterbenz) is pre-scaled by ``2^SPLIT_SHIFT`` and
    rounded to fp16 as the low part::

        x ≈ ldexp(hi + ldexp(lo, -SPLIT_SHIFT), e)      (~22 significand bits)

    Non-finite values ride the high part unchanged (``ldexp`` preserves
    inf/nan) with the residual zeroed there, so inf/nan propagate through the
    compensated contraction exactly as through an fp32 one.

    Args:
        x: fp32 array.
        axis: Contraction axis — each slice along it shares one exponent.

    Returns:
        ``(hi, lo, e)`` — fp16 parts shaped like ``x`` and the int32 exponent
        with ``keepdims`` shape (broadcastable against the contraction
        output).

    Example:
        >>> import jax.numpy as jnp
        >>> x = jnp.asarray([[3.0, 0.0078125]])
        >>> hi, lo, e = split_f16(x, axis=-1)
        >>> recon = jnp.ldexp(hi.astype(jnp.float32)
        ...                   + jnp.ldexp(lo.astype(jnp.float32), -SPLIT_SHIFT), e)
        >>> bool(jnp.all(recon == x))
        True
    """
    f32 = jnp.float32
    finite = jnp.isfinite(x)
    mag = jnp.where(finite, jnp.abs(x), jnp.zeros((), f32))
    _, e = jnp.frexp(jnp.max(mag, axis=axis, keepdims=True))
    xs = jnp.ldexp(x, -e)                               # max finite |xs| ∈ [½, 1)
    hi = xs.astype(jnp.float16)
    r = jnp.where(finite, xs - hi.astype(f32), jnp.zeros((), f32))
    lo = jnp.ldexp(r, SPLIT_SHIFT).astype(jnp.float16)
    return hi, lo, e


# ---------------------------------------------------------------------------
# The one precision-dispatched contraction every scan triangle goes through
# ---------------------------------------------------------------------------


def _mm(a, b, acc):
    return jnp.matmul(a, b, preferred_element_type=acc)


def pdot(a, b, *, acc, precision: str, exact: str = "none"):
    """Precision-dispatched ``a @ b`` with ``acc`` accumulation.

    The single contraction helper behind every matmul-engine scan triangle
    (``scan_mm``, ``scan_pipeline``, ``linrec_mm``, ``segscan_mm`` and the
    pure-jnp tile scans).  ``"highest"`` is exactly the existing
    ``jnp.matmul(..., preferred_element_type=acc)``; the jaxpr of a
    ``"highest"`` call is byte-identical to the pre-precision code.

    Operands marked ``exact`` (the 0/1 triangular constants ``U_s``/``L⁻_s``
    and their masked segmented variants) are representable in fp16/bf16
    without rounding and are cast, never split.  If any *data* operand is not
    fp32 — integer mask scans, native bf16/f16 feeds — or ``acc`` is not
    fp32, the call falls through to ``"highest"`` (exactness of integer
    scans is unconditional; sub-fp32 floats already feed the engine
    natively).

    Args:
        a: Left operand, ``(..., m, k)``.
        b: Right operand, ``(..., k, n)``.
        acc: Accumulation dtype (``preferred_element_type``).
        precision: One of ``PRECISIONS`` (already resolved).
        exact: Which operand is an exact 0/1 constant: ``"left"``,
            ``"right"`` or ``"none"`` (both are data; 3 fp16 products, the
            ``lo×lo`` term dropped).

    Returns:
        The product in ``acc``.

    Example:
        >>> import jax.numpy as jnp
        >>> a = jnp.asarray([[1.5, 2.5]])
        >>> u = jnp.triu(jnp.ones((2, 2), jnp.float32))
        >>> hp = pdot(a, u, acc=jnp.float32, precision="highest", exact="right")
        >>> cp = pdot(a, u, acc=jnp.float32, precision="compensated", exact="right")
        >>> bool(jnp.all(hp == cp))   # exactly representable inputs: bit-equal
        True
    """
    acc = jnp.dtype(acc)
    f32 = jnp.dtype(jnp.float32)
    data_f32 = acc == f32
    if exact != "left":
        data_f32 = data_f32 and jnp.dtype(a.dtype) == f32
    if exact != "right":
        data_f32 = data_f32 and jnp.dtype(b.dtype) == f32
    if precision == "highest" or not data_f32:
        return _mm(a, b, acc)
    if precision == "fast":
        return _mm(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16), acc).astype(acc)
    # compensated
    if exact == "right":
        hi, lo, e = split_f16(a, axis=-1)
        b16 = b.astype(jnp.float16)
        p = _mm(hi, b16, acc) + jnp.ldexp(_mm(lo, b16, acc), -SPLIT_SHIFT)
        return jnp.ldexp(p, e)
    if exact == "left":
        hi, lo, e = split_f16(b, axis=-2)
        a16 = a.astype(jnp.float16)
        p = _mm(a16, hi, acc) + jnp.ldexp(_mm(a16, lo, acc), -SPLIT_SHIFT)
        return jnp.ldexp(p, e)
    ah, al, ea = split_f16(a, axis=-1)
    bh, bl, eb = split_f16(b, axis=-2)
    p = _mm(ah, bh, acc) + jnp.ldexp(_mm(ah, bl, acc) + _mm(al, bh, acc),
                                     -SPLIT_SHIFT)
    return jnp.ldexp(p, ea + eb)
