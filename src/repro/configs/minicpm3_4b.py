"""minicpm3-4b [dense]: multi-head latent attention (MLA) with compressed KV cache
and absorbed-matrix decode. [hf:openbmb/MiniCPM3-4B; hf]"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="decoder",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448, head_dim=64,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
)

SMOKE = ModelConfig(
    name="minicpm3-4b-smoke", family="decoder",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    dtype="float32", remat=False,
)
