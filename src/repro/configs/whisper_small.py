"""whisper-small [audio]: enc-dec, conv frontend stubbed as precomputed frame
embeddings (input_specs provides (B, enc_len, d) — DESIGN.md §4).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    rope=False, act="gelu_nogate", enc_len=1500, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-small-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16,
    rope=False, act="gelu_nogate", enc_len=32, tie_embeddings=True, dtype="float32", remat=False,
)
