"""deepseek-moe-16b [moe]: fine-grained 64 routed experts top-6 + 2 shared experts,
first layer dense. [arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944,                       # the single dense layer
    vocab_size=102400,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  first_k_dense=1),
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=256, vocab_size=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=2,
                  first_k_dense=1, capacity_factor=16.0),
    dtype="float32", remat=False,
)
