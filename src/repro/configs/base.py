"""Config system: ModelConfig dataclass + the assigned input-shape registry."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    first_k_dense: int = 0          # leading dense layers (deepseek-moe)
    capacity_factor: float = 1.25
    router_scale: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    n_heads: int                    # SSM heads (d_inner / head_dim)
    head_dim: int
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 4            # every k-th block is sLSTM, rest mLSTM
    n_heads: int = 4
    proj_factor: float = 2.0        # mLSTM up-projection
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # decoder | encdec | moe | hybrid | xlstm | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention options
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    local_window: Optional[int] = None       # sliding-window size for local layers
    layer_pattern: Optional[Tuple[str, ...]] = None  # e.g. ("local","global") cycle
    rope_theta: float = 10000.0
    rope: bool = True
    tie_embeddings: bool = False
    act: str = "silu"               # mlp activation
    norm_eps: float = 1e-6
    scale_embed: bool = False       # gemma-style sqrt(d) embedding scale
    # submodel configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (zamba2): shared attention block every k ssm layers
    shared_attn_interval: Optional[int] = None
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_len: int = 1500             # audio frames after conv frontend (stub)
    # vlm (paligemma)
    n_img_tokens: int = 0           # patch embeddings prepended (stub frontend)
    # training / numerics
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    scan_method: str = "auto"       # tuning-table dispatch ("vector"/"matmul" to pin)
    # shapes this arch supports (skips documented in DESIGN.md §4)
    supports_long: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a multiple of 256 so the vocab axis
        shards evenly over any model-parallel degree ≤ 256 (padded logits are
        masked to -inf — see TransformerLM._logits)."""
        return ((self.vocab_size + 255) // 256) * 256

    def param_count(self) -> int:
        """Total parameters (for MODEL_FLOPS roofline)."""
        from repro.models.model import build_model
        import jax
        m = build_model(self)
        p = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
        return sum(int(jnp.prod(jnp.array(l.shape))) for l in jax.tree.leaves(p))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# smoke-test (reduced) shape
SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
