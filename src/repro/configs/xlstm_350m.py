"""xlstm-350m [ssm]: mLSTM (chunked matmul scan) + sLSTM (sequential — the
recurrence is non-associative; matmul-scan inapplicable, DESIGN.md §4) blocks,
3:1 ratio. [arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="xlstm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=4, n_heads=4, proj_factor=2.0, conv_kernel=4),
    rope=False, supports_long=True,
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke", family="xlstm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=256,
    xlstm=XLSTMConfig(slstm_every=4, n_heads=4, proj_factor=2.0, conv_kernel=4),
    rope=False, supports_long=True, dtype="float32", remat=False,
)
