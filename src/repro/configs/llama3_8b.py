"""llama3-8b [dense]: GQA, 128k vocab. [arXiv:2407.21783; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="decoder",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
)

SMOKE = ModelConfig(
    name="llama3-8b-smoke", family="decoder",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, rope_theta=500000.0, dtype="float32", remat=False,
)
