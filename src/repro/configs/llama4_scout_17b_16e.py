"""llama4-scout-17b-16e [moe]: 16 experts, top-1 routing + shared expert; text
backbone (early-fusion frontend out of scope per assignment). MoE dispatch offsets
come from the paper's int8 mask scan. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048, rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1),
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, rope_theta=500000.0,
    moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=128, n_shared=1,
                  capacity_factor=16.0),
    dtype="float32", remat=False,
)
