"""zamba2-1.2b [hybrid]: Mamba2 backbone + one *shared* attention block applied
every 6 mamba layers (weights shared across invocations; per-invocation KV cache).
The Mamba2 mixer runs on the chunked matmul scan. [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000,
    ssm=SSMConfig(d_state=64, n_heads=64, head_dim=64, expand=2,
                  conv_kernel=4, chunk=128, n_groups=1),
    shared_attn_interval=6, supports_long=True,
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    ssm=SSMConfig(d_state=8, n_heads=8, head_dim=16, expand=2,
                  conv_kernel=4, chunk=16, n_groups=1),
    shared_attn_interval=2, supports_long=True, dtype="float32", remat=False,
)
