"""qwen3-4b [dense]: GQA + per-head q/k RMSNorm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="decoder",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936, qk_norm=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke", family="decoder",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, qk_norm=True, rope_theta=1e6, dtype="float32", remat=False,
)
