"""gemma2-2b [dense]: local+global alternating attention, logit softcaps,
sandwich norms, tied embeddings. [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="decoder",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    layer_pattern=("local", "global"), local_window=4096,
    attn_softcap=50.0, final_softcap=30.0, scale_embed=True,
    tie_embeddings=True, act="gelu",
)

SMOKE = ModelConfig(
    name="gemma2-2b-smoke", family="decoder",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    layer_pattern=("local", "global"), local_window=16,
    attn_softcap=50.0, final_softcap=30.0, scale_embed=True,
    tie_embeddings=True, act="gelu", dtype="float32", remat=False,
)
