"""paligemma-3b [vlm]: SigLIP frontend stubbed as precomputed patch embeddings
(input_specs provides (B, 256, d)); gemma MQA backbone with prefix-LM attention
over the image tokens. [arXiv:2407.07726; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257216, act="gelu", scale_embed=True,
    tie_embeddings=True, n_img_tokens=256,
)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256, act="gelu", scale_embed=True,
    tie_embeddings=True, n_img_tokens=8, dtype="float32", remat=False,
)
