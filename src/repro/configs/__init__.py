"""Model configs (dataclasses) and the tuning-table package data."""
