"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scan import accum_dtype_for


def scan_ref(x: jax.Array, *, accum_dtype=None) -> jax.Array:
    """Oracle for ``scan_mm.scan_tiles``: plain cumsum in the accumulation dtype."""
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else accum_dtype_for(x.dtype)
    return jnp.cumsum(x.astype(acc), axis=-1, dtype=acc)


def ssd_ref(x, a_log, b_mat, c_mat):
    """Oracle for ``ssd_chunk.ssd_chunk_scan``: sequential recurrence over time."""
    from repro.core.ssd import ssd_scan_ref
    return ssd_scan_ref(x, a_log, b_mat, c_mat)


def split_ref(x: jax.Array, flags: jax.Array):
    """Oracle for ``split_mm.split_tiles``: the unfused scan+scatter SplitInd."""
    from repro.core.primitives import split
    return split(x, flags, method="vector")


def radix_sort_enc_ref(enc: jax.Array, *, bits: int):
    """Oracle for ``ops.radix_sort_enc_kernel``: unfused per-bit splits.

    Deliberately pinned to ``bits_per_pass=1`` — the paper's binary SplitInd
    formulation is the ground truth every multi-bit pass count must match.
    """
    from repro.core.primitives import dispatch
    return dispatch("radix_passes", "vector")(
        enc, bits, method="vector", tile_s=128, interpret=None,
        bits_per_pass=1)


def topp_mask_sample_ref(sorted_p: jax.Array, u: jax.Array, *, p: float):
    """Oracle for ``split_mm.topp_mask_sample_tiles`` (index into sorted order)."""
    sp = sorted_p.astype(jnp.float32)
    cum = jnp.cumsum(sp, axis=-1)
    cut = (cum - sp) > p
    masked = jnp.where(cut, 0.0, sp)
    cdf = jnp.cumsum(masked, axis=-1)
    theta = u.astype(jnp.float32) * cdf[..., -1:]
    j = jnp.sum((cdf < theta).astype(jnp.int32), axis=-1)
    return jnp.clip(j, 0, sorted_p.shape[-1] - 1)
