"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scan import accum_dtype_for


def scan_ref(x: jax.Array, *, accum_dtype=None) -> jax.Array:
    """Oracle for ``scan_mm.scan_tiles``: plain cumsum in the accumulation dtype."""
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else accum_dtype_for(x.dtype)
    return jnp.cumsum(x.astype(acc), axis=-1, dtype=acc)


def ssd_ref(x, a_log, b_mat, c_mat):
    """Oracle for ``ssd_chunk.ssd_chunk_scan``: sequential recurrence over time."""
    from repro.core.ssd import ssd_scan_ref
    return ssd_scan_ref(x, a_log, b_mat, c_mat)
