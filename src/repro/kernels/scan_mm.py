"""Fused Pallas TPU kernel for the paper's ScanU / ScanUL1 tile scans.

One kernel launch scans a whole (batch of) array(s): the grid is ``(batch, n_tiles)``
and TPU executes the tile dimension sequentially on a core, which gives us exactly the
paper's pipelined single-core loop (Alg. 1/2) — the MTE double-buffering of AscendC
queues is performed by the Pallas pipeline from ``BlockSpec``, and the running
``partial`` lives in SMEM scratch instead of a vector-core register.

Beyond-paper fusion: on Ascend the cube core writes the tile to GM and a *separate*
vector core re-reads it to add the carry (two extra GM trips).  On TPU the MXU and VPU
share VMEM, so the carry add is fused after the matmuls — the kernel moves 2N bytes
total, the theoretical minimum for scan.

dtypes follow the cube unit: fp32, bf16 (fp32 accumulate), int8 (int32 accumulate —
the paper's mask-scan specialization), int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import guards
from repro.core.precision import pdot
from repro.core.scan import accum_dtype_for

__all__ = ["scan_tiles", "scan_mm_kernel", "VARIANTS"]

# The two tile-scan algorithms of the paper (Alg. 1 ScanU / Alg. 2 ScanUL1).
VARIANTS = ("scanul1", "scanu")


def _kernel(x_ref, o_ref, carry_ref, *, variant: str, acc, precision: str):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        carry_ref[0, 0] = jnp.zeros((), acc)

    a = x_ref[0, 0]                                   # (s, s) tile in VMEM
    s = a.shape[-1]
    # U_s / L⁻_s are built in-register from iota comparisons (as split_mm
    # does) instead of being streamed from HBM as constant operands on every
    # launch — the only HBM traffic left is the tile itself.
    ri = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    u = (ri <= ci).astype(a.dtype)                    # U_s
    if variant == "scanul1":
        # Paper Eq. 1 — all three products on the MXU, C2 accumulated in place
        # (the L0C accumulation-buffer step of Alg. 2 line 12).
        c2 = pdot(a, u, acc=acc, precision=precision, exact="right")
        ones = jnp.ones((s, s), dtype=a.dtype)
        c1 = pdot(a, ones, acc=acc, precision=precision, exact="right")
        lm = (ri > ci).astype(acc)                    # L⁻_s
        c2 = c2 + pdot(lm, c1, acc=acc, precision=precision, exact="left")
        local = c2
    else:  # scanu
        # Alg. 1: one matmul for the s row-local scans; propagation of the row
        # partials on the VPU (log-depth cumsum; Ascend used a serial vector loop).
        local = pdot(a, u, acc=acc, precision=precision, exact="right")
        row_sums = local[:, -1]
        row_prefix = jnp.cumsum(row_sums, axis=0) - row_sums
        local = local + row_prefix[:, None]
    out = local + carry_ref[0, 0]
    carry_ref[0, 0] = out[-1, -1]
    o_ref[0, 0] = out


def scan_mm_kernel(variant: str, acc, s: int, interpret: bool,
                   precision: str = "highest"):
    kern = functools.partial(_kernel, variant=variant, acc=acc,
                             precision=precision)

    def call(tiles: jax.Array) -> jax.Array:
        b, nt = tiles.shape[0], tiles.shape[1]
        return pl.pallas_call(
            kern,
            grid=(b, nt),
            in_specs=[
                pl.BlockSpec((1, 1, s, s), lambda i, j: (i, j, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, s, s), lambda i, j: (i, j, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, nt, s, s), acc),
            scratch_shapes=[pltpu.SMEM((1, 1), acc)],
            interpret=interpret,
            name=f"scan_mm_{variant}_s{s}",
        )(tiles)

    return call


def scan_tiles(x: jax.Array, *, s: int = 128, variant: str = "scanul1",
               accum_dtype=None, interpret: bool | None = None,
               precision: str = "highest") -> jax.Array:
    """Scan the last axis of ``x`` (any leading batch dims) with the fused kernel."""
    variant = guards.validate_choice(variant, VARIANTS, name="variant",
                                     op="scan_tiles")
    s = guards.validate_positive(s, name="s", op="scan_tiles")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None else accum_dtype_for(x.dtype)
    *lead, n = x.shape
    ell = s * s
    xb = x.reshape(-1, n) if lead else x[None]
    b = xb.shape[0]
    pad = (-n) % ell
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad)))
    nt = xb.shape[-1] // ell
    tiles = xb.reshape(b, nt, s, s)
    out = scan_mm_kernel(variant, acc, s, interpret, precision)(tiles)
    out = out.reshape(b, nt * ell)[:, :n]
    return out.reshape(*lead, n) if lead else out[0]
