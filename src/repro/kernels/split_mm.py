"""Fused Pallas TPU kernels for the paper's scan-based operators (§5).

The paper's SplitInd is the building block of compress / radix sort / top-k /
top-p: an int8 mask scan produces destination offsets, then values (and their
indices) are permuted.  The pure-JAX path in ``repro.core.primitives`` runs the
mask scan and the scatter as separate XLA ops, so the scanned mask round-trips
through HBM between them.  Each kernel here performs the whole operator in one
launch per batch row:

* ``split_tiles``   — SplitInd: the int8 -> int32 mask scan runs on the MXU
  (``A @ U_s`` with ``U_s`` materialised in-register from iota comparisons, so
  no constant operand is streamed from HBM), destination offsets are computed
  on the VPU, and values + original indices are scattered — mask, offsets and
  destinations all stay in VMEM.
* ``multi_split_tiles`` — radix-2^k generalization of SplitInd: a stable
  ``R``-way bucket partition from one launch.  The ``(rows, R, s)`` int8
  one-hot digit matrix is built in-register and all ``R`` bucket mask scans
  run as a single batched ``A @ U_s`` MXU contraction; per-bucket bases come
  from a tiny ``R``-wide scan of the bucket totals.  This is the same
  matmul-scan trick Dakkak et al. use for TCU scans, applied to the paper's
  binary SplitInd so one radix pass retires ``k = log2(R)`` bits.
* ``radix_pass_multibit`` — one radix-2^k pass: k-bit digit extraction, the
  multi-way matmul split and the permutation of (keys, permutation) in a
  single launch; ``ceil(bits / k)`` of these sort a ``bits``-bit key.
  ``pass_bits=1`` *is* the paper's binary LSB pass (a 2-bucket split).
* ``topp_mask_sample_tiles`` — the tail of nucleus sampling fused: prefix sum
  of the sorted probabilities, the ``cum - p > threshold`` cutoff, the masked
  CDF and the inverse-transform sample, emitting only one int32 per row.

Ascend performs the post-scan permutation with vector-core gather/scatter
instructions; the analogue here is a jnp scatter inside the kernel.  That is
exact (integer destinations) and is what the interpret path — the CI target —
executes; on hardware it requires Mosaic dynamic-scatter support.  The top-p
kernel keeps its two prefix sums on the VPU (``jnp.cumsum``) so its output is
bit-identical to the unfused ``method="vector"`` reference; the MXU tile-scan
variant of the same prefix sum lives in ``scan_mm``.

dtype rule (paper's mask-scan specialization): the mask is fed to the MXU as
int8 and accumulated in int32, whatever the payload dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import guards

__all__ = ["split_tiles", "multi_split_tiles", "radix_pass_multibit",
           "topp_mask_sample_tiles"]


# ---------------------------------------------------------------------------
# Shared in-kernel SplitInd body
# ---------------------------------------------------------------------------


def _splitind_body(flags_row, payload_rows, *, s: int):
    """SplitInd on one (1, n) row held in VMEM.

    ``flags_row``: (1, n) values in {0, 1} (padding must be 0 — it then maps to
    the identity at the tail).  Returns (scattered payloads, original-index
    permutation, number of flagged elements).
    """
    n = flags_row.shape[-1]
    rows = n // s
    # --- int8 mask scan on the MXU (ScanU rows of width s) ---
    a = flags_row.reshape(rows, s).astype(jnp.int8)
    ri = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    u = (ri <= ci).astype(jnp.int8)                    # U_s, built in-register
    local = jax.lax.dot_general(a, u, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
    sums = local[:, -1:]
    prefix = jnp.cumsum(sums, axis=0) - sums           # VPU carry propagation
    inc = (local + prefix).reshape(1, n)               # inclusive mask scan
    # --- destination offsets (paper's SplitInd indexing) ---
    fi = flags_row.astype(jnp.int32)
    ex = inc - fi                                      # exclusive mask scan
    n_true = inc[0, -1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    dest = jnp.where(fi == 1, ex, n_true + iota - ex)[0]
    # --- permutation (Ascend: vector-core scatter; here: in-VMEM jnp scatter) ---
    outs = tuple(jnp.zeros_like(p).at[0, dest].set(p[0]) for p in payload_rows)
    ind = jnp.zeros((1, n), jnp.int32).at[0, dest].set(iota[0])
    return outs, ind, n_true


def _multisplit_body(digits_row, payload_rows, *, s: int, radix: int,
                     with_ind: bool = True):
    """Stable ``radix``-way split of one (1, n) row held in VMEM.

    ``digits_row``: (1, n) int32 bucket ids in ``[0, radix)``; padding must
    carry the maximum digit ``radix - 1`` so it lands (stably) at the tail.
    Returns (scattered payloads, original-index permutation or ``None``,
    per-bucket totals of shape (radix,)).
    """
    n = digits_row.shape[-1]
    rows = n // s
    d = digits_row.reshape(rows, 1, s)
    # --- (rows, R, s) one-hot digit matrix, built in-register ---
    bid = jax.lax.broadcasted_iota(jnp.int32, (rows, radix, s), 1)
    oh = (d == bid).astype(jnp.int8)
    # --- all R bucket mask scans as ONE batched A @ U_s MXU contraction ---
    ri = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    u = (ri <= ci).astype(jnp.int8)                    # U_s, in-register
    local = jax.lax.dot_general(oh, u, (((2,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
    sums = local[:, :, -1]                             # (rows, R) block totals
    prefix = jnp.cumsum(sums, axis=0) - sums           # VPU carry propagation
    inc = local + prefix[:, :, None]                   # inclusive bucket scans
    # --- per-bucket exclusive offsets (tiny R-wide scan of bucket totals) ---
    oh32 = oh.astype(jnp.int32)
    ex = inc - oh32                                    # exclusive within bucket
    totals = inc[-1, :, -1]                            # (R,) bucket counts
    base = jnp.cumsum(totals) - totals                 # exclusive bucket bases
    # dest_i = base[d_i] + ex[d_i, i]; the one-hot contraction keeps it on the VPU
    dest = jnp.sum(oh32 * (ex + base[None, :, None]), axis=1).reshape(n)
    # --- permutation (Ascend: vector-core scatter; here: in-VMEM jnp scatter) ---
    outs = tuple(jnp.zeros_like(p).at[0, dest].set(p[0]) for p in payload_rows)
    ind = None
    if with_ind:
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
        ind = jnp.zeros((1, n), jnp.int32).at[0, dest].set(iota[0])
    return outs, ind, totals


# ---------------------------------------------------------------------------
# split
# ---------------------------------------------------------------------------


def _split_kernel(x_ref, f_ref, z_ref, ind_ref, cnt_ref, *, s: int):
    (z,), ind, n_true = _splitind_body(f_ref[...], (x_ref[...],), s=s)
    z_ref[...] = z
    ind_ref[...] = ind
    cnt_ref[0, 0] = n_true


def split_tiles(x: jax.Array, flags: jax.Array, *, s: int = 128,
                interpret: bool | None = None):
    """Fused SplitInd over the last axis: ``(z, indices, n_true)``.

    ``x``: (..., n) payload; ``flags``: same shape, boolean/int.  One kernel
    launch per batch row; the row (padded to a multiple of ``s``) lives in VMEM.
    """
    guards.validate_same_shape(x.shape, jnp.shape(flags), op="split_tiles")
    s = guards.validate_positive(s, name="s", op="split_tiles")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    *lead, n = x.shape
    xb = x.reshape(-1, n)
    fb = flags.reshape(-1, n).astype(jnp.int8)
    b = xb.shape[0]
    pad = (-n) % s
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad)))
        fb = jnp.pad(fb, ((0, 0), (0, pad)))           # pad flags 0 -> identity tail
    np_ = xb.shape[-1]
    z, ind, cnt = pl.pallas_call(
        functools.partial(_split_kernel, s=s),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, np_), x.dtype),
            jax.ShapeDtypeStruct((b, np_), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
        name=f"split_mm_s{s}",
    )(xb, fb)
    z = z[:, :n].reshape(*lead, n)
    ind = ind[:, :n].reshape(*lead, n)
    cnt = cnt[:, 0].reshape(lead) if lead else cnt[0, 0]
    return z, ind, cnt


# ---------------------------------------------------------------------------
# multi-way split (radix-2^k SplitInd)
# ---------------------------------------------------------------------------


def _multi_split_kernel(x_ref, d_ref, z_ref, ind_ref, cnt_ref, *, s: int,
                        radix: int):
    (z,), ind, totals = _multisplit_body(d_ref[...], (x_ref[...],), s=s,
                                         radix=radix)
    z_ref[...] = z
    ind_ref[...] = ind
    cnt_ref[...] = totals.reshape(1, radix)


def multi_split_tiles(x: jax.Array, digits: jax.Array, *, num_buckets: int,
                      s: int = 128, interpret: bool | None = None):
    """Fused stable ``num_buckets``-way split: ``(z, indices, counts)``.

    ``x``: (..., n) payload; ``digits``: same shape, int bucket ids in
    ``[0, num_buckets)``.  One launch per batch row; the row (padded to a
    multiple of ``s`` with the maximum digit, so padding stays at the tail)
    lives in VMEM.  ``counts`` has shape ``(..., num_buckets)``.
    """
    guards.validate_same_shape(x.shape, jnp.shape(digits),
                               op="multi_split_tiles", b_name="digits")
    num_buckets = guards.validate_positive(num_buckets, name="num_buckets",
                                           op="multi_split_tiles")
    s = guards.validate_positive(s, name="s", op="multi_split_tiles")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    *lead, n = x.shape
    xb = x.reshape(-1, n)
    db = digits.reshape(-1, n).astype(jnp.int32)
    b = xb.shape[0]
    pad = (-n) % s
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad)))
        db = jnp.pad(db, ((0, 0), (0, pad)),
                     constant_values=num_buckets - 1)  # pads sort to the tail
    np_ = xb.shape[-1]
    z, ind, cnt = pl.pallas_call(
        functools.partial(_multi_split_kernel, s=s, radix=num_buckets),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, np_), lambda i: (i, 0)),
            pl.BlockSpec((1, num_buckets), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, np_), x.dtype),
            jax.ShapeDtypeStruct((b, np_), jnp.int32),
            jax.ShapeDtypeStruct((b, num_buckets), jnp.int32),
        ],
        interpret=interpret,
        name=f"multi_split_r{num_buckets}_s{s}",
    )(xb, db)
    if pad:
        cnt = cnt.at[:, -1].add(-pad)                  # padding landed in bucket R-1
    z = z[:, :n].reshape(*lead, n)
    ind = ind[:, :n].reshape(*lead, n)
    cnt = cnt.reshape(*lead, num_buckets)
    return z, ind, cnt


# ---------------------------------------------------------------------------
# radix pass (radix-2^k; pass_bits=1 is the paper's binary formulation)
# ---------------------------------------------------------------------------


def _radix_pass_multibit_kernel(w_ref, p_ref, wo_ref, po_ref, *, shift: int,
                                pass_bits: int, s: int):
    w = w_ref[...]
    mask = jnp.asarray((1 << pass_bits) - 1, w.dtype)
    digits = ((w >> shift) & mask).astype(jnp.int32)   # k-bit digit, ascending
    (wo, po), _, _ = _multisplit_body(digits, (w, p_ref[...]), s=s,
                                      radix=1 << pass_bits, with_ind=False)
    wo_ref[...] = wo
    po_ref[...] = po


def _radix_pass_multibit_hist_kernel(w_ref, p_ref, wo_ref, po_ref, cnt_ref, *,
                                     shift: int, pass_bits: int, s: int):
    w = w_ref[...]
    mask = jnp.asarray((1 << pass_bits) - 1, w.dtype)
    digits = ((w >> shift) & mask).astype(jnp.int32)   # k-bit digit, ascending
    (wo, po), _, totals = _multisplit_body(digits, (w, p_ref[...]), s=s,
                                           radix=1 << pass_bits, with_ind=False)
    wo_ref[...] = wo
    po_ref[...] = po
    cnt_ref[...] = totals.reshape(1, 1 << pass_bits)


def radix_pass_multibit(work: jax.Array, perm: jax.Array, *, shift: int,
                        pass_bits: int, s: int = 128,
                        interpret: bool | None = None,
                        with_counts: bool = False):
    """One fused radix-2^k pass on pre-padded (b, n) operands.

    ``work`` must be an unsigned encoding padded at the tail with the maximum
    key value, so padding sorts (stably) to the end and stays there across
    passes.  One launch retires ``pass_bits`` bits: the k-bit digit
    extraction, the ``2^k``-way matmul split and the permutation of both
    arrays are chained in a single launch, so ``ceil(bits / k)`` launches
    sort the full key — a ``k``-fold cut in HBM round-trips of the (keys,
    permutation) arrays.  ``pass_bits=1`` is exactly the paper's binary LSB
    pass (zeros-first split on one bit).

    With ``with_counts`` the per-bucket totals of the pass — the per-shard
    digit histogram the distributed sort's bucket exchange is built from
    (``repro.core.dist_ops``) — are exported as a third ``(b, 2^pass_bits)``
    int32 output, straight from the in-VMEM bucket mask scans (no second
    histogram launch).  Padding carries the maximum key, so its count lands
    entirely in bucket ``2^pass_bits - 1``; callers that padded must subtract
    it there (as :func:`multi_split_tiles` does).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    b, n = work.shape
    radix = 1 << pass_bits
    if with_counts:
        return pl.pallas_call(
            functools.partial(_radix_pass_multibit_hist_kernel, shift=shift,
                              pass_bits=pass_bits, s=s),
            grid=(b,),
            in_specs=[
                pl.BlockSpec((1, n), lambda i: (i, 0)),
                pl.BlockSpec((1, n), lambda i: (i, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, n), lambda i: (i, 0)),
                pl.BlockSpec((1, n), lambda i: (i, 0)),
                pl.BlockSpec((1, radix), lambda i: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((b, n), work.dtype),
                jax.ShapeDtypeStruct((b, n), jnp.int32),
                jax.ShapeDtypeStruct((b, radix), jnp.int32),
            ],
            interpret=interpret,
            name=f"radix_pass_multibit_hist_sh{shift}_k{pass_bits}_s{s}",
        )(work, perm)
    return pl.pallas_call(
        functools.partial(_radix_pass_multibit_kernel, shift=shift,
                          pass_bits=pass_bits, s=s),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), work.dtype),
            jax.ShapeDtypeStruct((b, n), jnp.int32),
        ],
        interpret=interpret,
        name=f"radix_pass_multibit_sh{shift}_k{pass_bits}_s{s}",
    )(work, perm)


# ---------------------------------------------------------------------------
# fused top-p tail (cumsum -> cutoff -> masked CDF -> inverse-transform sample)
# ---------------------------------------------------------------------------


def _topp_kernel(sp_ref, u_ref, j_ref, *, p: float, n_real: int):
    sp = sp_ref[...]                                   # (1, n) sorted probs, desc
    cum = jnp.cumsum(sp, axis=-1)
    cut = (cum - sp) > p                               # llama3 sample_top_p formula
    masked = jnp.where(cut, jnp.zeros_like(sp), sp)
    cdf = jnp.cumsum(masked, axis=-1)
    theta = u_ref[0, 0] * cdf[0, -1]
    j = jnp.sum((cdf < theta).astype(jnp.int32))
    j_ref[0, 0] = jnp.clip(j, 0, n_real - 1)


def topp_mask_sample_tiles(sorted_p: jax.Array, u: jax.Array, *, p: float,
                           interpret: bool | None = None) -> jax.Array:
    """Fused nucleus-sampling tail.

    ``sorted_p``: (..., n) probabilities sorted descending; ``u``: (..., 1)
    uniform draws.  Returns the (...,) int32 index *into the sorted order* —
    four elementwise/scan passes and a reduction in one launch, with only one
    scalar per row leaving VMEM.  Both prefix sums use the VPU cumsum so the
    result is bit-identical to the unfused ``method="vector"`` sampler.
    """
    guards.validate_probability(p, op="topp_mask_sample_tiles")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    *lead, n = sorted_p.shape
    sp = sorted_p.reshape(-1, n).astype(jnp.float32)
    ub = u.reshape(-1, 1).astype(jnp.float32)
    b = sp.shape[0]
    j = pl.pallas_call(
        functools.partial(_topp_kernel, p=p, n_real=n),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.int32),
        interpret=interpret,
        name="topp_mask_sample",
    )(sp, ub)
    return j[:, 0].reshape(lead) if lead else j[0, 0]
