"""Segmented matmul scan kernels: the carry resets at segment boundaries.

The paper scans one flat array; packed variable-length batches (MoE group
dispatch, continuous-batching decode, ragged data pipelines) need *segmented*
scans — prefix sums that restart at segment starts.  Dakkak et al. show
segmented scan is expressible on matrix engines with the same matmul
formulation the paper uses for ScanU/ScanUL1 (see PAPERS.md), and that is what
these kernels implement: the boundary-flag mask folds into the ``A @ U_s``
contraction in-register, and the §4 blocked pipeline's phase-2 carry scan
becomes a *segmented* carry scan, so multi-block ragged inputs still read and
write each element exactly once.

Representation: packed values ``(n,)`` plus int8 boundary flags ``(n,)`` where
``flags[i] = 1`` iff element ``i`` starts a new segment (derived from CSR-style
offsets by ``repro.core.segmented``).  Tiles/blocks are the same row-major
``(m, s)`` views as the unsegmented kernels.

Per-block algebra (the segmented analogue of paper Eq. 1), all built
in-register from ``broadcasted_iota`` like the PR 3 kernels:

* ``start[r, j]`` — the last flagged column ``<= j`` in row ``r`` (a ``cummax``
  of ``iota * flag``); the row-local segmented scans are then the masked
  contraction ``local[r, j] = sum_i A[r, i] * [start[r, j] <= i <= j]`` — the
  flag mask folded into the ``A @ U_s`` operand (tile kernel), or equivalently
  ``(A @ U_s)[r, j] - (A @ U_s - A)[r, start[r, j]]`` (rectangular blocked
  kernel, which avoids materialising an ``(m, s, s)`` mask for large blocks).
* row carries propagate under the segmented-pair operator
  ``(a ⊕ b) = (b.flagged ? b.sum : a.sum + b.sum)``: with ``ts[r]`` the row's
  trailing-segment sum and ``lastb[r]`` the last boundary-carrying row before
  ``r``, the carry into row ``r`` is ``sum_{q=lastb[r]}^{r-1} ts[q]`` — again a
  masked triangular contraction on the MXU.
* an incoming block/tile carry is added only where no boundary has been seen
  since the block start (``seen`` mask); the outgoing carry is simply
  ``out[-1, -1]`` (the scan value at the block end *is* the trailing-segment
  sum).

As in ``split_mm``, the in-kernel gathers (`take_along_axis` of the row-start
indices) are what Ascend would issue as vector-core gather instructions; the
interpret path — the CI target — executes them exactly, and on hardware they
require Mosaic dynamic-gather support.

dtype rules follow ``accum_dtype_for``: int8/bool flags and values accumulate
in int32 (the paper's mask-scan specialization), bf16/f16 in fp32.

Every boundary-masked contraction goes through :func:`repro.core.precision.pdot`
— the masked triangular operands stay exact 0/1 matrices under fp16/bf16, so
``precision="compensated"``/``"fast"`` apply to segmented scans with the same
ulp contract as the unsegmented kernels (integer mask scans stay exact
unconditionally; only the start-column *gather* path subtracts two compensated
products, which the ulp oracle covers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import guards
from repro.core.precision import pdot
from repro.core.scan import _operand_dtype, accum_dtype_for

__all__ = ["seg_scan_tiles", "seg_blocked_scan", "seg_block_summaries",
           "seg_carry_scan", "seg_block_scan_carry"]


def _default_interpret() -> bool:
    """Interpret everywhere but TPU (same policy as ``scan_pipeline``)."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# In-kernel segmented block algebra (shared by the tile and blocked kernels)
# ---------------------------------------------------------------------------


def _row_starts(f32: jax.Array) -> jax.Array:
    """``start[r, j]`` = last flagged column ``<= j`` in row ``r`` (0 if none).

    ``f32``: (m, s) int32 flags.  Built from a ``cummax`` over
    ``iota * flag`` — the in-register analogue of streaming a per-tile
    boundary index vector from HBM.
    """
    m, s = f32.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (m, s), 1)
    return jax.lax.cummax(jnp.where(f32 > 0, pos, 0), axis=1)


def _seg_rows_masked(a: jax.Array, startc: jax.Array, acc,
                     precision: str = "highest") -> jax.Array:
    """Row-local segmented scans via the flag-masked ``A @ U_s`` contraction.

    ``mask[r, i, j] = (start[r, j] <= i <= j)`` folds the boundary flags into
    the upper-triangular ones operand, so one batched MXU contraction yields
    every row's segmented scan.  Used by the square tile kernel (``m == s``);
    the rectangular blocked kernel uses :func:`_seg_rows_gather` to avoid the
    ``(m, s, s)`` mask tensor.
    """
    m, s = a.shape
    ri = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    cj = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    tri = ri <= cj                                     # U_s, in-register
    mseg = (tri[None, :, :] & (ri[None, :, :] >= startc[:, None, :]))
    mseg = mseg.astype(a.dtype)
    # Batched matmul over the row dimension — the per-row masked U_s operand
    # is still an exact 0/1 matrix, so pdot's "right" split applies per row.
    local = pdot(a[:, None, :], mseg, acc=acc, precision=precision,
                 exact="right")
    return local[:, 0, :].astype(acc)


def _seg_rows_gather(a: jax.Array, startc: jax.Array, acc,
                     precision: str = "highest") -> jax.Array:
    """Row-local segmented scans via ``A @ U_s`` + a start-column gather.

    ``local_seg[r, j] = (A @ U_s)[r, j] - exclusive(A @ U_s)[r, start[r, j]]``
    — exact for the integer/mask dtypes (and integer-valued floats) the
    operators feed it, and O(m·s) scratch instead of the O(m·s²) mask of
    :func:`_seg_rows_masked`; this is what the rectangular blocked kernel
    uses.
    """
    s = a.shape[-1]
    ri = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    cj = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    u = (ri <= cj).astype(a.dtype)                     # U_s, in-register
    full = pdot(a, u, acc=acc, precision=precision,
                exact="right").astype(acc)
    ex = full - a.astype(acc)                          # exclusive row scans
    base = jnp.take_along_axis(ex, startc, axis=1)     # value before seg start
    return full - base


def _seg_row_carries(ts: jax.Array, hrow: jax.Array, acc,
                     precision: str = "highest") -> jax.Array:
    """Exclusive segmented carry over rows: ``c[r] = sum ts[lastb[r] .. r-1]``.

    ``ts``: (m,) per-row trailing-segment sums; ``hrow``: (m,) bool
    row-has-boundary.  ``lastb[r]`` is the last boundary-carrying row strictly
    before ``r`` (0 if none) — rows before it belong to earlier segments and
    must not leak in.  The sum is one masked triangular contraction on the
    MXU (the ScanUL1 ``L⁻`` product with the segment mask folded in).
    """
    m = ts.shape[0]
    rowi = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)[:, 0]
    lastb_inc = jax.lax.cummax(jnp.where(hrow, rowi, 0), axis=0)
    lastb_ex = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), lastb_inc[:-1]])
    qi = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    rj = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    m2 = ((qi < rj) & (qi >= lastb_ex[None, :])).astype(acc)
    return pdot(ts[None, :], m2, acc=acc, precision=precision,
                exact="right")[0]


def _seg_block_scan(a: jax.Array, f32: jax.Array, acc, *, masked: bool,
                    precision: str = "highest"):
    """Segmented scan of one (m, s) row-major block held in VMEM.

    Returns ``(out, seen)`` where ``out`` is the block-local segmented scan
    (no incoming carry) and ``seen[r, j]`` is true iff a boundary occurs at or
    before element ``(r, j)`` — the positions an incoming carry must NOT
    touch.
    """
    startc = _row_starts(f32)
    rows = _seg_rows_masked if masked else _seg_rows_gather
    local = rows(a, startc, acc, precision)
    ts = local[:, -1]                                  # trailing-segment sums
    hrow = jnp.max(f32, axis=1) > 0
    c = _seg_row_carries(ts, hrow, acc, precision)
    seen_row = jax.lax.cummax(f32, axis=1) > 0         # boundary <= j in row
    out = local + jnp.where(seen_row, jnp.zeros((), acc), c[:, None])
    prev = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jax.lax.cummax(hrow.astype(jnp.int32), axis=0)[:-1]])
    seen = seen_row | (prev[:, None] > 0)
    return out, seen


# ---------------------------------------------------------------------------
# Sequential-grid fused kernel (the segmented analogue of scan_mm)
# ---------------------------------------------------------------------------


def _seg_kernel(x_ref, f_ref, o_ref, carry_ref, *, acc, precision):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        carry_ref[0, 0] = jnp.zeros((), acc)

    a = x_ref[0, 0]                                    # (s, s) tile in VMEM
    f32 = f_ref[0, 0].astype(jnp.int32)
    out, seen = _seg_block_scan(a, f32, acc, masked=True, precision=precision)
    out = out + jnp.where(seen, jnp.zeros((), acc), carry_ref[0, 0])
    carry_ref[0, 0] = out[-1, -1]                      # trailing-segment sum
    o_ref[0, 0] = out


def seg_scan_tiles(x: jax.Array, flags: jax.Array, *, s: int = 128,
                   accum_dtype=None, interpret: bool | None = None,
                   precision: str = "highest") -> jax.Array:
    """Segmented scan of the last axis with one sequential-grid launch.

    ``x``: ``(..., n)`` packed values; ``flags``: same shape, nonzero where an
    element starts a new segment.  Tiles are walked in order with the
    SMEM-carried running partial of ``scan_mm``; the carry is gated by the
    in-tile ``seen`` mask so it never crosses a boundary.
    """
    guards.validate_broadcastable_to(jnp.shape(flags), x.shape,
                                     op="seg_scan_tiles")
    s = guards.validate_positive(s, name="s", op="seg_scan_tiles")
    if interpret is None:
        interpret = _default_interpret()
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else accum_dtype_for(x.dtype)
    *lead, n = x.shape
    xb = x.reshape(-1, n) if lead else x[None]
    if xb.dtype == jnp.bool_:
        xb = xb.astype(_operand_dtype(xb.dtype))
    fb = jnp.broadcast_to(flags.astype(jnp.int8), x.shape).reshape(xb.shape)
    b = xb.shape[0]
    ell = s * s
    pad = (-n) % ell
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad)))
        fb = jnp.pad(fb, ((0, 0), (0, pad)))           # pad joins last segment
    nt = xb.shape[-1] // ell
    tiles = xb.reshape(b, nt, s, s)
    ftiles = fb.reshape(b, nt, s, s)
    spec = pl.BlockSpec((1, 1, s, s), lambda i, j: (i, j, 0, 0))
    out = pl.pallas_call(
        functools.partial(_seg_kernel, acc=acc, precision=precision),
        grid=(b, nt),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, nt, s, s), acc),
        scratch_shapes=[pltpu.SMEM((1, 1), acc)],
        interpret=interpret,
        name=f"segscan_mm_s{s}",
    )(tiles, ftiles)
    out = out.reshape(b, nt * ell)[:, :n]
    return out.reshape(*lead, n) if lead else out[0]


# ---------------------------------------------------------------------------
# Blocked pipeline (§4) with a segmented phase-2 carry scan
# ---------------------------------------------------------------------------


def _seg_summary_kernel(x_ref, f_ref, ts_ref, h_ref, *, acc):
    a = x_ref[0, 0]                                    # (m, s) block view
    f32 = f_ref[0, 0].astype(jnp.int32)
    m, s = a.shape
    rank = (jax.lax.broadcasted_iota(jnp.int32, (m, s), 0) * s +
            jax.lax.broadcasted_iota(jnp.int32, (m, s), 1))
    lastpos = jnp.max(jnp.where(f32 > 0, rank, 0))
    trailing = jnp.where(rank >= lastpos, a.astype(acc), jnp.zeros((), acc))
    ts_ref[0, 0] = jnp.sum(trailing)
    h_ref[0, 0] = jnp.max(f32)


def seg_block_summaries(blocks: jax.Array, fblocks: jax.Array, *,
                        accum_dtype=None, interpret: bool | None = None):
    """Phase 1 summary pass: ``(trailing sums, has-boundary)`` per block.

    The unsegmented pipeline's phase 1 reduces each block to one sum; the
    segmented pair ``(ts, h)`` is its analogue under the segmented-scan
    operator: ``ts`` is the sum of elements after the block's last boundary
    and ``h`` records whether the block contains any boundary.  Reads N
    elements, writes 2·nb scalars; no dependency on the partial scans.
    """
    if interpret is None:
        interpret = _default_interpret()
    b, nb, m, s = blocks.shape
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else accum_dtype_for(blocks.dtype)
    spec = pl.BlockSpec((1, 1, m, s), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        functools.partial(_seg_summary_kernel, acc=acc),
        grid=(b, nb),
        in_specs=[spec, spec],
        out_specs=(pl.BlockSpec((1, 1), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j))),
        out_shape=(jax.ShapeDtypeStruct((b, nb), acc),
                   jax.ShapeDtypeStruct((b, nb), jnp.int32)),
        interpret=interpret,
        name=f"segscan_pipeline_summaries_m{m}_s{s}",
    )(blocks, fblocks)


def _seg_carry_kernel(ts_ref, h_ref, o_ref, *, precision):
    ts = ts_ref[0, :]
    hrow = h_ref[0, :] > 0
    o_ref[0, :] = _seg_row_carries(ts, hrow, ts.dtype, precision)


def seg_carry_scan(sums: jax.Array, has_boundary: jax.Array, *,
                   interpret: bool | None = None,
                   precision: str = "highest") -> jax.Array:
    """Phase 2: exclusive *segmented* scan of the block summaries.

    This is the tentpole change to the §4 pipeline: the plain exclusive cumsum
    of block sums becomes a scan under the segmented-pair operator
    ``(a ⊕ b) = (b.h ? b.ts : a.ts + b.ts)`` — a carry never crosses a block
    that contains a boundary.  ``nb`` is tiny, so one masked triangular
    contraction per batch row suffices.
    """
    if interpret is None:
        interpret = _default_interpret()
    b, nb = sums.shape
    return pl.pallas_call(
        functools.partial(_seg_carry_kernel, precision=precision),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, nb), lambda i: (i, 0)),
                  pl.BlockSpec((1, nb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nb), sums.dtype),
        interpret=interpret,
        name=f"segscan_pipeline_carry_nb{nb}",
    )(sums, has_boundary)


def _seg_block_carry_kernel(x_ref, f_ref, c_ref, o_ref, *, acc, precision):
    a = x_ref[0, 0]
    f32 = f_ref[0, 0].astype(jnp.int32)
    out, seen = _seg_block_scan(a, f32, acc, masked=False, precision=precision)
    o_ref[0, 0] = out + jnp.where(seen, jnp.zeros((), acc), c_ref[0, 0])


def seg_block_scan_carry(blocks: jax.Array, fblocks: jax.Array,
                         carries: jax.Array, *, accum_dtype=None,
                         interpret: bool | None = None,
                         precision: str = "highest") -> jax.Array:
    """Fused phases 1+3: block-local segmented scan + gated carry add.

    Each grid step reads its block once, runs the segmented block algebra in
    VMEM, adds the block carry only where no boundary has been seen since the
    block start, and writes the result once — the §4 read/write-once property
    extended to ragged inputs.
    """
    if interpret is None:
        interpret = _default_interpret()
    b, nb, m, s = blocks.shape
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else accum_dtype_for(blocks.dtype)
    spec = pl.BlockSpec((1, 1, m, s), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        functools.partial(_seg_block_carry_kernel, acc=acc,
                          precision=precision),
        grid=(b, nb),
        in_specs=[spec, spec, pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, nb, m, s), acc),
        interpret=interpret,
        name=f"segscan_pipeline_m{m}_s{s}",
    )(blocks, fblocks, carries)


def seg_blocked_scan(x: jax.Array, flags: jax.Array, *, s: int = 128,
                     block_tiles: int = 8, accum_dtype=None,
                     interpret: bool | None = None,
                     precision: str = "highest") -> jax.Array:
    """Segmented scan of the last axis with the three-phase blocked pipeline.

    Same decomposition as ``scan_pipeline.blocked_scan``: phase 1 computes
    per-block ``(trailing sum, has-boundary)`` summaries, phase 2 runs the
    *segmented* carry scan over them, and fused phases 1+3 produce the final
    segmented scan with each element read and written once.
    """
    guards.validate_broadcastable_to(jnp.shape(flags), x.shape,
                                     op="seg_blocked_scan")
    s = guards.validate_positive(s, name="s", op="seg_blocked_scan")
    block_tiles = guards.validate_positive(block_tiles, name="block_tiles",
                                           op="seg_blocked_scan")
    if interpret is None:
        interpret = _default_interpret()
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else accum_dtype_for(x.dtype)
    *lead, n = x.shape
    xb = x.reshape(-1, n) if lead else x[None]
    if xb.dtype == jnp.bool_:
        xb = xb.astype(_operand_dtype(xb.dtype))
    fb = jnp.broadcast_to(flags.astype(jnp.int8), x.shape).reshape(xb.shape)
    b = xb.shape[0]
    ell = s * s
    t = max(1, min(block_tiles, -(-n // ell)))
    m = t * s
    block_len = m * s
    pad = (-n) % block_len
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad)))
        fb = jnp.pad(fb, ((0, 0), (0, pad)))
    nb = xb.shape[-1] // block_len
    blocks = xb.reshape(b, nb, m, s)
    fblocks = fb.reshape(b, nb, m, s)
    if nb == 1:
        carries = jnp.zeros((b, 1), acc)
    else:
        sums, h = seg_block_summaries(blocks, fblocks, accum_dtype=acc,
                                      interpret=interpret)
        carries = seg_carry_scan(sums, h, interpret=interpret,
                                 precision=precision)
    out = seg_block_scan_carry(blocks, fblocks, carries, accum_dtype=acc,
                               interpret=interpret, precision=precision)
    out = out.reshape(b, nb * block_len)[:, :n]
    return out.reshape(*lead, n) if lead else out[0]
