"""Blocked multi-core scan pipeline (paper §4, Alg. 3) as Pallas grid kernels.

The paper's MCScan splits a length-``N`` input into ``B`` blocks and runs three
phases across Ascend AI cores:

  Phase 1  every block in parallel: the cube units compute a matmul *partial*
           scan of the block while the vector units independently *recompute*
           the block reduction ``r_i`` (so the reductions never wait on the
           scans).
  Phase 2  the ``B`` block sums are scanned (exclusive) to produce per-block
           carries.
  Phase 3  each block broadcast-adds its carry onto its partial scan.

TPU mapping (one launch per phase, grid = blocks):

* ``block_partial_sums`` — the phase-1 *vector recompute*: a cheap reduction
  pass over the raw input (reads N elements, writes B scalars).  Keeping it a
  separate launch is what lets the main kernel below be single-pass.
* ``carry_scan`` — phase 2: an exclusive scan of the ``(batch, B)`` block sums
  on the VPU (log-depth cumsum; B is tiny compared to N).
* ``block_scan_carry`` — phases 1+3 *fused*: per-block matmul partial scans
  (the ScanU/ScanUL1 tile algebra from :mod:`repro.core.scan`, generalized to
  a rectangular ``m×s`` row-major block view) plus the carry broadcast-add in
  the same launch.  Each element is read from HBM once and written once — on
  Ascend the carry add is a separate vector-core pass over GM (two extra trips);
  on TPU the MXU and VPU share VMEM so the add fuses behind the matmuls.

Traffic: ``N`` (sum pass) + ``N`` read + ``N`` write + ``O(B)``, vs. the
unfused 2 reads + 2 writes per element; the paper reports 74.9% of memcpy
bandwidth for the fused pipeline, which ``benchmarks/run.py --only
scan_pipeline`` tracks as ``memcpy_frac``.

Block algebra (paper Eq. 1 on a rectangular block): with ``A`` the ``m×s``
row-major view of one block, ``scan(A) = A@U_s + carry_rows(A@1_s)`` where
``carry_rows`` is the exclusive prefix of the ``m`` row sums — a VPU cumsum for
``variant="scanu"`` (Alg. 1) or a strictly-lower-triangular ``L⁻_m`` matvec on
the MXU for ``variant="scanul1"`` (Alg. 2).

dtype rules follow ``accum_dtype_for``: int8/bool masks accumulate in int32
(the paper's mask-scan specialization), bf16/f16 in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.precision import pdot
from repro.core.scan import _operand_dtype, accum_dtype_for

__all__ = ["blocked_scan", "block_partial_sums", "carry_scan", "block_scan_carry"]


def _default_interpret() -> bool:
    """Interpret everywhere but TPU (one policy for all pipeline phases).

    These kernels target Mosaic, and ``mcscan``'s default path must keep
    working on CPU *and* GPU hosts, so non-TPU backends run the Pallas
    interpreter rather than attempting a native lowering.
    """
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Phase 1 (vector recompute): per-block reductions
# ---------------------------------------------------------------------------


def _block_sums_kernel(x_ref, o_ref, *, acc):
    o_ref[0, 0] = jnp.sum(x_ref[0, 0].astype(acc))


def block_partial_sums(blocks: jax.Array, *, accum_dtype=None,
                       interpret: bool | None = None) -> jax.Array:
    """Phase 1 reduction pass: block sums of ``(b, nb, m, s)`` -> ``(b, nb)``.

    This is the paper's vector-unit *recompute* of the block reductions: it
    reads the raw input once and has no data dependency on the partial scans,
    so the scheduler can overlap it with (or run it ahead of) the main scan
    launch.
    """
    if interpret is None:
        interpret = _default_interpret()
    b, nb, m, s = blocks.shape
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else accum_dtype_for(blocks.dtype)
    return pl.pallas_call(
        functools.partial(_block_sums_kernel, acc=acc),
        grid=(b, nb),
        in_specs=[pl.BlockSpec((1, 1, m, s), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, nb), acc),
        interpret=interpret,
        name=f"scan_pipeline_block_sums_m{m}_s{s}",
    )(blocks)


# ---------------------------------------------------------------------------
# Phase 2: exclusive scan of the block sums (the carries)
# ---------------------------------------------------------------------------


def _carry_scan_kernel(r_ref, o_ref):
    row = r_ref[0, :]
    inc = jnp.cumsum(row, axis=0)
    o_ref[0, :] = jnp.concatenate([jnp.zeros((1,), row.dtype), inc[:-1]])


def carry_scan(sums: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Phase 2: exclusive prefix of the ``(b, nb)`` block sums, per batch row.

    ``nb`` is small (N / block_len), so a single log-depth VPU cumsum per batch
    row suffices — the analogue of the paper's phase-2 scan of ``r`` in UB.
    """
    if interpret is None:
        interpret = _default_interpret()
    b, nb = sums.shape
    return pl.pallas_call(
        _carry_scan_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, nb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nb), sums.dtype),
        interpret=interpret,
        name=f"scan_pipeline_carry_scan_nb{nb}",
    )(sums)


# ---------------------------------------------------------------------------
# Phases 1+3 fused: per-block matmul partial scan + carry broadcast-add
# ---------------------------------------------------------------------------


def _upper_ones_in_register(s: int, dtype):
    """``U_s`` from iota comparisons — no HBM constant operand per launch."""
    ri = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    return (ri <= ci).astype(dtype)


def _block_scan_scanu_kernel(x_ref, c_ref, o_ref, *, acc, precision):
    a = x_ref[0, 0]                                        # (m, s) block view
    u = _upper_ones_in_register(a.shape[-1], a.dtype)
    local = pdot(a, u, acc=acc, precision=precision, exact="right").astype(acc)
    row_sums = local[:, -1]                                # == A @ 1_s
    row_prefix = jnp.cumsum(row_sums, axis=0) - row_sums   # exclusive, VPU
    o_ref[0, 0] = local + row_prefix[:, None] + c_ref[0, 0]


def _block_scan_scanul1_kernel(x_ref, c_ref, o_ref, *, acc, precision):
    a = x_ref[0, 0]
    m = a.shape[0]
    u = _upper_ones_in_register(a.shape[-1], a.dtype)
    local = pdot(a, u, acc=acc, precision=precision, exact="right").astype(acc)
    row_sums = local[:, -1]
    # Paper Eq. 1 on the rectangular block: L⁻_m @ (A @ 1_s) on the MXU;
    # L⁻_m is likewise built in-register (strict lower triangle of ones).
    ri = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    ci = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    lm = (ri > ci).astype(acc)
    row_prefix = pdot(lm, row_sums[:, None], acc=acc, precision=precision,
                      exact="left")[:, 0]
    o_ref[0, 0] = local + row_prefix[:, None] + c_ref[0, 0]


def block_scan_carry(blocks: jax.Array, carries: jax.Array, *,
                     variant: str = "scanul1", accum_dtype=None,
                     interpret: bool | None = None,
                     precision: str = "highest") -> jax.Array:
    """Fused phases 1+3: matmul partial scan of each block + carry add.

    ``blocks``: ``(b, nb, m, s)`` row-major block views; ``carries``: ``(b,
    nb)`` exclusive block prefixes from :func:`carry_scan`.  One grid step
    reads its block from HBM once, runs the ScanU/ScanUL1 algebra in VMEM, adds
    the block carry, and writes the final result once — the read/write-once
    property the paper obtains by overlapping cube and vector units.
    """
    if interpret is None:
        interpret = _default_interpret()
    b, nb, m, s = blocks.shape
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else accum_dtype_for(blocks.dtype)
    block_spec = pl.BlockSpec((1, 1, m, s), lambda i, j: (i, j, 0, 0))
    carry_spec = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    if variant == "scanul1":
        kern = functools.partial(_block_scan_scanul1_kernel, acc=acc,
                                 precision=precision)
    elif variant == "scanu":
        kern = functools.partial(_block_scan_scanu_kernel, acc=acc,
                                 precision=precision)
    else:
        raise ValueError(f"unknown scan variant {variant!r}")
    # U_s / L⁻_m are built in-register inside the kernels from iota
    # comparisons, so the only operands streamed from HBM are the data blocks
    # and the nb carries.
    return pl.pallas_call(
        kern,
        grid=(b, nb),
        in_specs=[block_spec, carry_spec],
        out_specs=block_spec,
        out_shape=jax.ShapeDtypeStruct((b, nb, m, s), acc),
        interpret=interpret,
        name=f"scan_pipeline_{variant}_m{m}_s{s}",
    )(blocks, carries)


# ---------------------------------------------------------------------------
# Full pipeline
# ---------------------------------------------------------------------------


def blocked_scan(x: jax.Array, *, s: int = 128, block_tiles: int = 8,
                 variant: str = "scanul1", accum_dtype=None,
                 interpret: bool | None = None,
                 precision: str = "highest") -> jax.Array:
    """Scan the last axis of ``x`` with the three-phase blocked pipeline.

    ``x``: ``(..., n)`` for any ``n >= 1`` (ragged tails are zero-padded to a
    whole number of blocks and sliced off).  A block holds ``block_tiles``
    tiles of ``ell = s*s`` elements, viewed as an ``(block_tiles*s, s)``
    row-major matrix; ``block_tiles`` is clamped so a short input never pays
    for more than one partially-filled block.  Returns the inclusive scan in
    the accumulation dtype (``accum_dtype_for(x.dtype)`` unless overridden).
    """
    if variant not in ("scanu", "scanul1"):
        raise ValueError(f"unknown scan variant {variant!r}")
    if interpret is None:
        interpret = _default_interpret()
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else accum_dtype_for(x.dtype)
    *lead, n = x.shape
    xb = x.reshape(-1, n) if lead else x[None]
    if xb.dtype == jnp.bool_:
        xb = xb.astype(_operand_dtype(xb.dtype))
    b = xb.shape[0]
    ell = s * s
    t = max(1, min(block_tiles, -(-n // ell)))   # tiles per block, clamped
    m = t * s                                    # rows per block
    block_len = m * s
    pad = (-n) % block_len
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad)))
    nb = xb.shape[-1] // block_len
    blocks = xb.reshape(b, nb, m, s)
    if nb == 1:
        # Single block: the carry is provably zero — skip phases 1-2 entirely
        # (saves a full extra read of the input plus two launches).
        carries = jnp.zeros((b, 1), acc)
    else:
        sums = block_partial_sums(blocks, accum_dtype=acc, interpret=interpret)
        carries = carry_scan(sums, interpret=interpret)
    out = block_scan_carry(blocks, carries, variant=variant, accum_dtype=acc,
                           interpret=interpret, precision=precision)
    out = out.reshape(b, nb * block_len)[:, :n]
    return out.reshape(*lead, n) if lead else out[0]
