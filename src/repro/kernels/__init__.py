"""Pallas TPU kernels for the paper's compute hot-spots (validated interpret=True)."""
from repro.kernels.ops import (
    scan_kernel, blocked_scan_kernel, ssd_kernel, split_kernel,
    multi_split_kernel, radix_sort_enc_kernel, topp_mask_sample_kernel,
    seg_scan_kernel, seg_blocked_scan_kernel, linrec_kernel,
    linrec_blocked_kernel,
)
