"""Fused linear-recurrence (``y_t = a_t*y_{t-1} + b_t``) matmul-scan kernels.

The weighted-triangular tile algebra lives in :mod:`repro.core.linrec`
(``_pair_w`` / ``_linrec_block``); this module wraps it in the same two launch
shapes as the prefix-scan kernels:

* :func:`linrec_scan_tiles` — the ``scan_mm`` analogue: one sequential-grid
  launch walks ``(s, s)`` tiles in order with the running state ``y`` in SMEM
  scratch.  On the sequential grid the general affine carry ``(Π a, sum)``
  degenerates: each tile folds the incoming state immediately
  (``local + mult * y_in``), so only the scalar ``y`` needs carrying — the
  full affine pair appears where summaries must compose *out of order*, i.e.
  in the blocked pipeline's phase 2 below.
* the §4 blocked pipeline (:func:`linrec_blocked_scan`): phase 1 reduces each
  block to its affine summary ``(Π a, trailing affine sum)`` with cheap
  suffix-product dot products (no ``W`` contraction — the vector-unit
  recompute of the paper, and therefore precision-neutral: only the phase-2
  carry scan and the fused phase-1+3 contractions honour ``precision=``),
  phase 2 scans the ``nb`` summaries under affine
  composition (one weighted-triangular contraction per batch row), and fused
  phases 1+3 rerun the block algebra once with the carry folded in, so every
  element is read from HBM once and written once.

As in ``segscan_mm``, the in-kernel ``cumprod``/``cummax`` steps are what
Ascend would issue as vector-core instructions; the interpret path — the CI
target — executes them exactly, and on hardware they require Mosaic
cumulative-op support.  dtype rules follow ``linrec_accum_dtype_for``
(floats widen per ``accum_dtype_for``; integers accumulate in fp32 — the
weighted triangle divides cumulative products).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import guards
from repro.core.linrec import _linrec_block, _linrec_matmul, \
    linrec_accum_dtype_for

__all__ = ["linrec_scan_tiles", "linrec_blocked_scan", "linrec_block_summaries",
           "linrec_carry_scan", "linrec_block_scan_carry"]


def _default_interpret() -> bool:
    """Interpret everywhere but TPU (same policy as ``scan_pipeline``)."""
    return jax.default_backend() != "tpu"


def _pad_affine(ab, widths):
    """Pad an ``(a, b)`` pair with the identity affine element ``a=1, b=0``."""
    a, b = ab
    return jnp.pad(a, widths, constant_values=1), jnp.pad(b, widths)


def _to_rows(a, b, n):
    """Flatten leading dims to one batch axis of packed length-``n`` rows."""
    lead = a.shape[:-1]
    ab = a.reshape(-1, n) if lead else a[None]
    bb = b.reshape(-1, n) if lead else b[None]
    return ab, bb, lead


# ---------------------------------------------------------------------------
# Sequential-grid fused kernel (the linrec analogue of scan_mm)
# ---------------------------------------------------------------------------


def _tile_kernel(a_ref, b_ref, o_ref, carry_ref, *, acc, precision):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        carry_ref[0, 0] = jnp.zeros((), acc)   # running state y

    a = a_ref[0, 0]                            # (s, s) tile in VMEM
    b = b_ref[0, 0]
    out, mult = _linrec_block(a, b, acc, precision)
    out = out + mult * carry_ref[0, 0]
    carry_ref[0, 0] = out[-1, -1]
    o_ref[0, 0] = out


def linrec_scan_tiles(a: jax.Array, b: jax.Array, *, s: int = 128,
                      accum_dtype=None, interpret: bool | None = None,
                      precision: str = "highest") -> jax.Array:
    """Linear recurrence over the last axis in one sequential-grid launch.

    ``a``/``b``: ``(..., n)`` (already broadcast to a common shape by
    ``linear_scan``).  Tiles of ``ℓ = s²`` elements are walked in order; the
    SMEM scratch carries the running state across tiles (the affine carry's
    ``Π a`` half is never consumed on a sequential walk — module docstring).
    """
    guards.validate_same_shape(a.shape, b.shape, op="linrec_scan_tiles",
                               a_name="a", b_name="b")
    s = guards.validate_positive(s, name="s", op="linrec_scan_tiles")
    if interpret is None:
        interpret = _default_interpret()
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else linrec_accum_dtype_for(jnp.result_type(a.dtype, b.dtype))
    n = a.shape[-1]
    ab, bb, lead = _to_rows(a, b, n)
    rows = ab.shape[0]
    ell = s * s
    pad = (-n) % ell
    if pad:
        ab, bb = _pad_affine((ab, bb), ((0, 0), (0, pad)))
    nt = ab.shape[-1] // ell
    atiles = ab.reshape(rows, nt, s, s)
    btiles = bb.reshape(rows, nt, s, s)
    spec = pl.BlockSpec((1, 1, s, s), lambda i, j: (i, j, 0, 0))
    out = pl.pallas_call(
        functools.partial(_tile_kernel, acc=acc, precision=precision),
        grid=(rows, nt),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, nt, s, s), acc),
        scratch_shapes=[pltpu.SMEM((1, 1), acc)],
        interpret=interpret,
        name=f"linrec_mm_s{s}",
    )(atiles, btiles)
    out = out.reshape(rows, nt * ell)[:, :n]
    return out.reshape(*lead, n) if lead else out[0]


# ---------------------------------------------------------------------------
# Blocked pipeline (§4) with an affine phase-2 carry scan
# ---------------------------------------------------------------------------


def _suffix_prods_excl(a, acc, axis):
    """Exclusive suffix products ``Π_{k > j} a_k`` along ``axis`` (exact, no division)."""
    rev = jnp.flip(a.astype(acc), axis=axis)
    cp = jnp.flip(jnp.cumprod(rev, axis=axis), axis=axis)
    shifted = jax.lax.slice_in_dim(cp, 1, None, axis=axis)
    ones = jnp.ones_like(jax.lax.slice_in_dim(cp, 0, 1, axis=axis))
    return jnp.concatenate([shifted, ones], axis=axis)


def _summary_kernel(a_ref, b_ref, p_ref, l_ref, *, acc):
    a = a_ref[0, 0]                                    # (m, s) block view
    b = b_ref[0, 0].astype(acc)
    row_suf = _suffix_prods_excl(a, acc, axis=1)       # Π a after j, in-row
    rl = jnp.sum(b * row_suf, axis=1)                  # row-local last values
    rp = jnp.prod(a.astype(acc), axis=1)               # row products
    rows_suf = _suffix_prods_excl(rp, acc, axis=0)     # Π of later rows
    l_ref[0, 0] = jnp.sum(rl * rows_suf)               # trailing affine sum
    p_ref[0, 0] = jnp.prod(rp)                         # block product


def linrec_block_summaries(ablocks: jax.Array, bblocks: jax.Array, *,
                           accum_dtype=None, interpret: bool | None = None):
    """Phase 1 summary pass: the affine pair ``(Π a, trailing sum)`` per block.

    The prefix pipeline reduces each block to one sum; the linear recurrence
    reduces it to the affine map it applies to an incoming state —
    ``y_out = p * y_in + l``.  Both components are suffix-product dot
    products (O(m·s) vector work, no ``W`` contraction), so this pass stays
    the cheap no-dependency recompute of the paper's phase 1.
    """
    if interpret is None:
        interpret = _default_interpret()
    rows, nb, m, s = ablocks.shape
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else linrec_accum_dtype_for(jnp.result_type(ablocks.dtype, bblocks.dtype))
    spec = pl.BlockSpec((1, 1, m, s), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        functools.partial(_summary_kernel, acc=acc),
        grid=(rows, nb),
        in_specs=[spec, spec],
        out_specs=(pl.BlockSpec((1, 1), lambda i, j: (i, j)),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j))),
        out_shape=(jax.ShapeDtypeStruct((rows, nb), acc),
                   jax.ShapeDtypeStruct((rows, nb), acc)),
        interpret=interpret,
        name=f"linrec_pipeline_summaries_m{m}_s{s}",
    )(ablocks, bblocks)


def _carry_kernel(p_ref, l_ref, o_ref, *, acc, precision):
    p = p_ref[0, :]
    lv = l_ref[0, :]
    # inclusive affine scan of the summaries; the chunked form keeps every
    # in-register window inside the exponent-normalized range even when the
    # block count exceeds MAX_TILE
    inc = _linrec_matmul(p, lv, method="matmul", tile_s=128, block_tiles=0,
                         accum_dtype=acc, precision=precision)
    o_ref[0, :] = jnp.concatenate([jnp.zeros((1,), acc), inc[:-1]])


def linrec_carry_scan(prods: jax.Array, lasts: jax.Array, *,
                      interpret: bool | None = None,
                      precision: str = "highest") -> jax.Array:
    """Phase 2: exclusive scan of the block summaries under affine composition.

    ``carry_in[c] = Σ_{q<c} l_q · Π_{r=q+1..c-1} p_r`` — the state entering
    block ``c`` — computed as one weighted-triangular contraction per batch
    row (``nb`` is tiny compared to N, as in the prefix pipeline's phase 2).
    """
    if interpret is None:
        interpret = _default_interpret()
    rows, nb = prods.shape
    acc = prods.dtype
    return pl.pallas_call(
        functools.partial(_carry_kernel, acc=acc, precision=precision),
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, nb), lambda i: (i, 0)),
                  pl.BlockSpec((1, nb), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, nb), acc),
        interpret=interpret,
        name=f"linrec_pipeline_carry_nb{nb}",
    )(prods, lasts)


def _block_carry_kernel(a_ref, b_ref, c_ref, o_ref, *, acc, precision):
    a = a_ref[0, 0]
    b = b_ref[0, 0]
    out, mult = _linrec_block(a, b, acc, precision)
    o_ref[0, 0] = out + mult * c_ref[0, 0]


def linrec_block_scan_carry(ablocks: jax.Array, bblocks: jax.Array,
                            carries: jax.Array, *, accum_dtype=None,
                            interpret: bool | None = None,
                            precision: str = "highest") -> jax.Array:
    """Fused phases 1+3: block-local recurrence + carry fold, one read/write.

    Each grid step reads its block once, runs the weighted-triangular block
    algebra in VMEM, folds the incoming state via the block multiplier
    (``out + mult * carry``), and writes the result once — the §4
    read/write-once property carried over to linear recurrences.
    """
    if interpret is None:
        interpret = _default_interpret()
    rows, nb, m, s = ablocks.shape
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else linrec_accum_dtype_for(jnp.result_type(ablocks.dtype, bblocks.dtype))
    spec = pl.BlockSpec((1, 1, m, s), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        functools.partial(_block_carry_kernel, acc=acc, precision=precision),
        grid=(rows, nb),
        in_specs=[spec, spec, pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, nb, m, s), acc),
        interpret=interpret,
        name=f"linrec_pipeline_m{m}_s{s}",
    )(ablocks, bblocks, carries)


def linrec_blocked_scan(a: jax.Array, b: jax.Array, *, s: int = 128,
                        block_tiles: int = 8, accum_dtype=None,
                        interpret: bool | None = None,
                        precision: str = "highest") -> jax.Array:
    """Linear recurrence over the last axis with the three-phase blocked pipeline.

    Same decomposition as ``scan_pipeline.blocked_scan``: phase 1 computes the
    per-block affine summaries, phase 2 composes them into per-block incoming
    states, and fused phases 1+3 produce the final recurrence with each
    element read and written once.  Single-block inputs skip phases 1–2 (the
    incoming state is provably zero).
    """
    guards.validate_same_shape(a.shape, b.shape, op="linrec_blocked_scan",
                               a_name="a", b_name="b")
    s = guards.validate_positive(s, name="s", op="linrec_blocked_scan")
    block_tiles = guards.validate_positive(block_tiles, name="block_tiles",
                                           op="linrec_blocked_scan")
    if interpret is None:
        interpret = _default_interpret()
    acc = jnp.dtype(accum_dtype) if accum_dtype is not None \
        else linrec_accum_dtype_for(jnp.result_type(a.dtype, b.dtype))
    n = a.shape[-1]
    ab, bb, lead = _to_rows(a, b, n)
    rows = ab.shape[0]
    ell = s * s
    t = max(1, min(block_tiles, -(-n // ell)))
    m = t * s
    block_len = m * s
    pad = (-n) % block_len
    if pad:
        ab, bb = _pad_affine((ab, bb), ((0, 0), (0, pad)))
    nb = ab.shape[-1] // block_len
    ablocks = ab.reshape(rows, nb, m, s)
    bblocks = bb.reshape(rows, nb, m, s)
    if nb == 1:
        carries = jnp.zeros((rows, 1), acc)
    else:
        prods, lasts = linrec_block_summaries(ablocks, bblocks,
                                              accum_dtype=acc,
                                              interpret=interpret)
        carries = linrec_carry_scan(prods, lasts, interpret=interpret,
                                    precision=precision)
    out = linrec_block_scan_carry(ablocks, bblocks, carries, accum_dtype=acc,
                                  interpret=interpret, precision=precision)
    out = out.reshape(rows, nb * block_len)[:, :n]
    return out.reshape(*lead, n) if lead else out[0]
