"""Pallas TPU kernel for the chunked gated-linear-recurrence (SSD) scan.

The sequence is processed chunk-by-chunk on the sequential Pallas grid; the inter-chunk
state (the analogue of the paper's running ``partial``) lives in VMEM scratch, so — as
in ``scan_mm`` — the whole recurrence is one kernel with 2·(bytes of q,k,v,gates)
HBM traffic and *all* O(S·Q) work as MXU matmuls:

    cs      = a_row @ U_Q                      (cumsum of log-decays — paper Eq. 1 form)
    scores  = (C @ B^T) ∘ exp(cs_i - cs_j)     masked causal
    y       = scores @ X + (C ∘ exp(cs)) @ state
    state   = exp(cs_Q) * state + (B ∘ exp(cs_Q - cs))^T @ X
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.scan import upper_ones

__all__ = ["ssd_chunk_scan"]


def _kernel(x_ref, a_ref, b_ref, c_ref, u_ref, o_ref, state_ref, *, q: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)               # (Q, P)
    a = a_ref[0, 0].astype(jnp.float32)               # (1, Q) log decays
    bm = b_ref[0, 0].astype(jnp.float32)              # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)              # (Q, N)

    # cumsum of log decays via triangular matmul (the paper's A @ U identity).
    cs = jnp.dot(a, u_ref[...].astype(jnp.float32),
                 preferred_element_type=jnp.float32)[0]          # (Q,)

    li = cs[:, None] - cs[None, :]
    causal = jnp.tril(jnp.ones((q, q), jnp.bool_))
    lmat = jnp.where(causal, jnp.exp(li), 0.0)

    scores = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32) * lmat
    y = jnp.dot(scores, x, preferred_element_type=jnp.float32)

    state = state_ref[...]                            # (N, P)
    y = y + jnp.dot(cm * jnp.exp(cs)[:, None], state,
                    preferred_element_type=jnp.float32)

    total = cs[-1]
    decay_to_end = jnp.exp(total - cs)
    new_state = jnp.exp(total) * state + jnp.dot(
        (bm * decay_to_end[:, None]).T, x, preferred_element_type=jnp.float32)
    state_ref[...] = new_state
    o_ref[0, 0] = y.astype(o_ref.dtype)


def ssd_chunk_scan(x: jax.Array, a_log: jax.Array, b_mat: jax.Array,
                   c_mat: jax.Array, *, chunk: int = 128,
                   interpret: bool | None = None) -> jax.Array:
    """x: (B,S,H,P); a_log: (B,S,H); b_mat/c_mat: (B,S,H,N) -> y: (B,S,H,P)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // q

    def to_bh(t, feat):
        # (B,S,H,F) -> (B*H, nc, Q, F)
        t = jnp.moveaxis(t, 2, 1).reshape(bsz * h, sp, feat)
        return t.reshape(bsz * h, nc, q, feat)

    xb = to_bh(x, p)
    ab = to_bh(a_log[..., None], 1).reshape(bsz * h, nc, 1, q)
    bb = to_bh(b_mat, n)
    cb = to_bh(c_mat, n)
    u = upper_ones(q, jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((q, q), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz * h, nc, q, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
        name=f"ssd_chunk_q{q}",
    )(xb, ab, bb, cb, u)

    y = out.reshape(bsz, h, sp, p)
    y = jnp.moveaxis(y, 1, 2)[:, :s]
    return y
