"""jit'd public wrappers for the Pallas kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.linrec_mm import linrec_blocked_scan, linrec_scan_tiles
from repro.kernels.scan_mm import scan_tiles
from repro.kernels.scan_pipeline import blocked_scan
from repro.kernels.segscan_mm import seg_blocked_scan, seg_scan_tiles
from repro.kernels.split_mm import (
    multi_split_tiles,
    radix_pass_multibit,
    split_tiles,
    topp_mask_sample_tiles,
)
from repro.kernels.ssd_chunk import ssd_chunk_scan

__all__ = ["scan_kernel", "blocked_scan_kernel", "ssd_kernel", "split_kernel",
           "multi_split_kernel", "radix_pass_kernel", "radix_sort_enc_kernel",
           "topp_mask_sample_kernel", "seg_scan_kernel",
           "seg_blocked_scan_kernel", "linrec_kernel",
           "linrec_blocked_kernel"]


@functools.partial(jax.jit, static_argnames=("s", "variant", "accum_dtype",
                                             "interpret", "precision"))
def scan_kernel(x: jax.Array, *, s: int = 128, variant: str = "scanul1",
                accum_dtype=None, interpret: bool | None = None,
                precision: str = "highest") -> jax.Array:
    """Fused matmul-scan over the last axis (ScanU/ScanUL1, paper Alg. 1/2)."""
    return scan_tiles(x, s=s, variant=variant, accum_dtype=accum_dtype,
                      interpret=interpret, precision=precision)


@functools.partial(jax.jit, static_argnames=("s", "block_tiles", "variant",
                                             "accum_dtype", "interpret",
                                             "precision"))
def blocked_scan_kernel(x: jax.Array, *, s: int = 128, block_tiles: int = 8,
                        variant: str = "scanul1", accum_dtype=None,
                        interpret: bool | None = None,
                        precision: str = "highest") -> jax.Array:
    """Three-phase blocked scan pipeline (paper §4 MCScan, one device)."""
    return blocked_scan(x, s=s, block_tiles=block_tiles, variant=variant,
                        accum_dtype=accum_dtype, interpret=interpret,
                        precision=precision)


@functools.partial(jax.jit, static_argnames=("s", "accum_dtype", "interpret",
                                             "precision"))
def seg_scan_kernel(x: jax.Array, flags: jax.Array, *, s: int = 128,
                    accum_dtype=None, interpret: bool | None = None,
                    precision: str = "highest") -> jax.Array:
    """Fused segmented matmul scan: carry resets at flagged boundaries."""
    return seg_scan_tiles(x, flags, s=s, accum_dtype=accum_dtype,
                          interpret=interpret, precision=precision)


@functools.partial(jax.jit, static_argnames=("s", "block_tiles",
                                             "accum_dtype", "interpret",
                                             "precision"))
def seg_blocked_scan_kernel(x: jax.Array, flags: jax.Array, *, s: int = 128,
                            block_tiles: int = 8, accum_dtype=None,
                            interpret: bool | None = None,
                            precision: str = "highest") -> jax.Array:
    """§4 blocked pipeline with a segmented phase-2 carry scan."""
    return seg_blocked_scan(x, flags, s=s, block_tiles=block_tiles,
                            accum_dtype=accum_dtype, interpret=interpret,
                            precision=precision)


@functools.partial(jax.jit, static_argnames=("s", "accum_dtype", "interpret",
                                             "precision"))
def linrec_kernel(a: jax.Array, b: jax.Array, *, s: int = 128,
                  accum_dtype=None, interpret: bool | None = None,
                  precision: str = "highest") -> jax.Array:
    """Fused linear-recurrence tile scan (running state carried in SMEM)."""
    return linrec_scan_tiles(a, b, s=s, accum_dtype=accum_dtype,
                             interpret=interpret, precision=precision)


@functools.partial(jax.jit, static_argnames=("s", "block_tiles",
                                             "accum_dtype", "interpret",
                                             "precision"))
def linrec_blocked_kernel(a: jax.Array, b: jax.Array, *, s: int = 128,
                          block_tiles: int = 8, accum_dtype=None,
                          interpret: bool | None = None,
                          precision: str = "highest") -> jax.Array:
    """§4 blocked pipeline with an affine phase-2 carry scan."""
    return linrec_blocked_scan(a, b, s=s, block_tiles=block_tiles,
                               accum_dtype=accum_dtype, interpret=interpret,
                               precision=precision)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_kernel(x, a_log, b_mat, c_mat, *, chunk: int = 128,
               interpret: bool | None = None):
    """Fused chunked SSD scan (gated linear recurrence on the MXU)."""
    return ssd_chunk_scan(x, a_log, b_mat, c_mat, chunk=chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("s", "interpret"))
def split_kernel(x: jax.Array, flags: jax.Array, *, s: int = 128,
                 interpret: bool | None = None):
    """Fused SplitInd (paper §5): ``(z, indices, n_true)`` in one launch/row."""
    return split_tiles(x, flags, s=s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("num_buckets", "s", "interpret"))
def multi_split_kernel(x: jax.Array, digits: jax.Array, *, num_buckets: int,
                       s: int = 128, interpret: bool | None = None):
    """Fused radix-2^k SplitInd: ``(z, indices, counts)`` in one launch/row."""
    return multi_split_tiles(x, digits, num_buckets=num_buckets, s=s,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("shift", "pass_bits", "s",
                                             "interpret", "with_counts"))
def radix_pass_kernel(work: jax.Array, perm: jax.Array, *, shift: int,
                      pass_bits: int, s: int = 128,
                      interpret: bool | None = None,
                      with_counts: bool = False):
    """One fused radix-2^k pass; ``with_counts`` exports the digit histogram.

    Thin jitted wrapper over :func:`repro.kernels.split_mm.radix_pass_multibit`
    — the per-shard pass of the distributed sort (``repro.core.dist_ops``)
    calls this with ``with_counts=True`` so the bucket-exchange bases come out
    of the same launch that groups the shard.
    """
    return radix_pass_multibit(work, perm, shift=shift, pass_bits=pass_bits,
                               s=s, interpret=interpret,
                               with_counts=with_counts)


@functools.partial(jax.jit, static_argnames=("bits", "bits_per_pass", "s",
                                             "interpret"))
def radix_sort_enc_kernel(enc: jax.Array, *, bits: int, bits_per_pass: int = 1,
                          s: int = 128, interpret: bool | None = None):
    """Stable LSB radix sort of an unsigned encoding via fused radix passes.

    ``enc``: (..., n) unsigned keys (see ``primitives._encode_for_sort``).
    Returns ``(sorted_enc, permutation)``.  One ``radix_pass_multibit`` launch
    per ``bits_per_pass``-bit digit — ``ceil(bits / bits_per_pass)`` launches
    total (a ragged final digit just uses the remaining bits); the tail is
    padded once with the maximum key so it stays at the end across passes.
    """
    *lead, n = enc.shape
    work = enc.reshape(-1, n)
    b = work.shape[0]
    pad = (-n) % s
    if pad:
        fill = jnp.full((b, pad), jnp.iinfo(enc.dtype).max, enc.dtype)
        work = jnp.concatenate([work, fill], axis=-1)
    perm = jnp.broadcast_to(jnp.arange(work.shape[-1], dtype=jnp.int32),
                            work.shape)
    for shift in range(0, bits, bits_per_pass):
        k = min(bits_per_pass, bits - shift)
        work, perm = radix_pass_multibit(work, perm, shift=shift, pass_bits=k,
                                         s=s, interpret=interpret)
    work = work[:, :n].reshape(*lead, n)
    perm = perm[:, :n].reshape(*lead, n)
    return work, perm


@functools.partial(jax.jit, static_argnames=("p", "interpret"))
def topp_mask_sample_kernel(sorted_p: jax.Array, u: jax.Array, *, p: float,
                            interpret: bool | None = None) -> jax.Array:
    """Fused nucleus-sampling tail: index into the sorted order, one launch."""
    return topp_mask_sample_tiles(sorted_p, u, p=p, interpret=interpret)
