"""jit'd public wrappers for the Pallas kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.scan_mm import scan_tiles
from repro.kernels.ssd_chunk import ssd_chunk_scan

__all__ = ["scan_kernel", "ssd_kernel"]


@functools.partial(jax.jit, static_argnames=("s", "variant", "accum_dtype", "interpret"))
def scan_kernel(x: jax.Array, *, s: int = 128, variant: str = "scanul1",
                accum_dtype=None, interpret: bool | None = None) -> jax.Array:
    """Fused matmul-scan over the last axis (ScanU/ScanUL1, paper Alg. 1/2)."""
    return scan_tiles(x, s=s, variant=variant, accum_dtype=accum_dtype,
                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_kernel(x, a_log, b_mat, c_mat, *, chunk: int = 128,
               interpret: bool | None = None):
    """Fused chunked SSD scan (gated linear recurrence on the MXU)."""
    return ssd_chunk_scan(x, a_log, b_mat, c_mat, chunk=chunk, interpret=interpret)
