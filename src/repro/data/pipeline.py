"""Deterministic, restart-safe data pipeline.

Batches are a pure function of ``(seed, step, shard)`` — a restarted or elastically
resized job replays the exact stream with no data loss or duplication (the Trainer
persists only the step counter in the checkpoint).  Two sources:

  * ``SyntheticLM``: a fixed-order Markov-ish token stream (structured enough for a
    ~100M model to visibly learn within a few hundred steps);
  * ``ByteCorpus``: byte-level tokens from a text file, chunked deterministically;
  * ``PackedSyntheticLM``: the packed-sequence mode — variable-length documents
    packed back to back into one fixed token budget with CSR-style offsets, the
    layout the segmented-scan subsystem (``repro.core.segmented``) consumes.

Host-side prefetch keeps ``prefetch`` batches in flight (overlap input with step).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Sequence

import numpy as np


def pack_ragged(seqs: Sequence[np.ndarray]) -> Dict[str, np.ndarray]:
    """Pack variable-length token sequences into CSR-style (values, offsets).

    Returns ``{"tokens": (n,), "offsets": (len(seqs)+1,), "segment_ids": (n,)}``
    — the host-side mirror of ``repro.core.segmented.SegmentedBatch`` (empty
    sequences become repeated offsets).
    """
    arrs = [np.asarray(s).reshape(-1) for s in seqs]
    lens = np.asarray([a.shape[0] for a in arrs], np.int64)
    offsets = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    tokens = (np.concatenate(arrs) if arrs and offsets[-1]
              else np.zeros((0,), np.int32))
    seg_ids = np.repeat(np.arange(len(arrs), dtype=np.int32), lens)
    return {"tokens": tokens.astype(np.int32), "offsets": offsets,
            "segment_ids": seg_ids}


class SyntheticLM:
    """Deterministic synthetic language: a noisy affine bigram chain.

    ``x[t+1] = (a·x[t] + c) mod V`` with fixed (a, c); 10% of tokens are replaced
    by noise (and the chain continues from the observed token), so next-token is
    a *bigram* function predictable 90% of the time — CE drops toward
    ``0.1·ln(V) + H(0.9/0.1)`` within tens of steps once the model learns the
    token map, giving a cheap end-to-end training signal.
    """

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, a: int = 5, c: int = 17):
        self.vocab = int(vocab_size)
        self.seq = int(seq_len)
        self.batch = int(batch_size)
        self.seed = int(seed)
        self.a, self.c = a, c

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1) -> Dict:
        rows = self.batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        toks = np.empty((rows, self.seq), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, rows)
        noise = rng.random((rows, self.seq)) < 0.1
        rand = rng.integers(0, self.vocab, (rows, self.seq))
        for t in range(1, self.seq):
            nxt = (self.a * toks[:, t - 1] + self.c) % self.vocab
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PackedSyntheticLM:
    """Packed variable-length batches: ragged documents in one fixed budget.

    Every batch holds exactly ``tokens_per_batch // num_shards`` tokens split
    into ``num_docs`` variable-length documents (CSR offsets; empty documents
    are legal and do occur) — the continuous-batching / packed-pretraining
    layout, sharded over the token budget like the sibling sources shard over
    rows.
    Each document is an independent ``SyntheticLM``-style affine bigram chain
    restarting at its boundary, and batches are a pure function of
    ``(seed, step, shard)`` like every other source here, so shapes are static
    under jit while the segment layout stays ragged.
    """

    def __init__(self, vocab_size: int, tokens_per_batch: int, num_docs: int,
                 seed: int = 0, a: int = 5, c: int = 17):
        assert num_docs >= 1 and tokens_per_batch >= 1
        self.vocab = int(vocab_size)
        self.budget = int(tokens_per_batch)
        self.num_docs = int(num_docs)
        self.seed = int(seed)
        self.a, self.c = a, c

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1) -> Dict:
        budget = max(self.budget // num_shards, 1)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        cuts = np.sort(rng.integers(0, budget + 1, self.num_docs - 1))
        offsets = np.concatenate([[0], cuts, [budget]]).astype(np.int32)
        lens = offsets[1:] - offsets[:-1]
        # one row-vectorized chain per document (as SyntheticLM does across
        # batch rows), packed afterwards — no per-token Python loop
        width = int(lens.max())
        rows = np.empty((self.num_docs, width), np.int64)
        noise = rng.random((self.num_docs, width)) < 0.1
        rand = rng.integers(0, self.vocab, (self.num_docs, width))
        rows[:, 0] = rand[:, 0]                        # fresh chain per doc
        for t in range(1, width):
            nxt = (self.a * rows[:, t - 1] + self.c) % self.vocab
            rows[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        toks = rows[np.arange(width)[None, :] < lens[:, None]]
        seg_ids = np.repeat(np.arange(self.num_docs, dtype=np.int32), lens)
        return {"tokens": toks.astype(np.int32), "offsets": offsets,
                "segment_ids": seg_ids}

    def __iter__(self) -> Iterator[Dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ByteCorpus:
    """Byte-level LM batches from a file, deterministic in (seed, step)."""

    def __init__(self, path: str, seq_len: int, batch_size: int, seed: int = 0):
        with open(path, "rb") as f:
            self.data = np.frombuffer(f.read(), dtype=np.uint8)
        assert len(self.data) > seq_len + 1, "corpus too small"
        self.seq = seq_len
        self.batch = batch_size
        self.seed = seed

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1) -> Dict:
        rows = self.batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        starts = rng.integers(0, len(self.data) - self.seq - 1, rows)
        toks = np.stack([self.data[s:s + self.seq] for s in starts])
        return {"tokens": toks.astype(np.int32)}


class Prefetcher:
    """Background-thread prefetch of ``source.batch_at(step)``."""

    def __init__(self, source, start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.source.batch_at(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
