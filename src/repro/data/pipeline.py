"""Deterministic, restart-safe data pipeline.

Batches are a pure function of ``(seed, step, shard)`` — a restarted or elastically
resized job replays the exact stream with no data loss or duplication (the Trainer
persists only the step counter in the checkpoint).  Two sources:

  * ``SyntheticLM``: a fixed-order Markov-ish token stream (structured enough for a
    ~100M model to visibly learn within a few hundred steps);
  * ``ByteCorpus``: byte-level tokens from a text file, chunked deterministically.

Host-side prefetch keeps ``prefetch`` batches in flight (overlap input with step).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np


class SyntheticLM:
    """Deterministic synthetic language: a noisy affine bigram chain.

    ``x[t+1] = (a·x[t] + c) mod V`` with fixed (a, c); 10% of tokens are replaced
    by noise (and the chain continues from the observed token), so next-token is
    a *bigram* function predictable 90% of the time — CE drops toward
    ``0.1·ln(V) + H(0.9/0.1)`` within tens of steps once the model learns the
    token map, giving a cheap end-to-end training signal.
    """

    def __init__(self, vocab_size: int, seq_len: int, batch_size: int,
                 seed: int = 0, a: int = 5, c: int = 17):
        self.vocab = int(vocab_size)
        self.seq = int(seq_len)
        self.batch = int(batch_size)
        self.seed = int(seed)
        self.a, self.c = a, c

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1) -> Dict:
        rows = self.batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        toks = np.empty((rows, self.seq), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, rows)
        noise = rng.random((rows, self.seq)) < 0.1
        rand = rng.integers(0, self.vocab, (rows, self.seq))
        for t in range(1, self.seq):
            nxt = (self.a * toks[:, t - 1] + self.c) % self.vocab
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class ByteCorpus:
    """Byte-level LM batches from a file, deterministic in (seed, step)."""

    def __init__(self, path: str, seq_len: int, batch_size: int, seed: int = 0):
        with open(path, "rb") as f:
            self.data = np.frombuffer(f.read(), dtype=np.uint8)
        assert len(self.data) > seq_len + 1, "corpus too small"
        self.seq = seq_len
        self.batch = batch_size
        self.seed = seed

    def batch_at(self, step: int, shard: int = 0, num_shards: int = 1) -> Dict:
        rows = self.batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        starts = rng.integers(0, len(self.data) - self.seq - 1, rows)
        toks = np.stack([self.data[s:s + self.seq] for s in starts])
        return {"tokens": toks.astype(np.int32)}


class Prefetcher:
    """Background-thread prefetch of ``source.batch_at(step)``."""

    def __init__(self, source, start_step: int = 0, prefetch: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.source.batch_at(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
