"""Data pipeline — packed ragged batches for the segmented subsystem."""
