"""Serving — rectangular ServeEngine + continuous-batching ContinuousEngine.

``engine.ServeEngine``: dense (rectangular) prefill/decode with the paper's
scan-based top-p sampler — the ``kv_layout="dense"`` baseline.
``scheduler.ContinuousEngine``: FCFS continuous batching over the paged KV
cache (``paged_kv``), with an in-graph ``lax.while_loop`` multi-token decode.
"""
from repro.serving.engine import ServeEngine
from repro.serving.paged_kv import PageAllocator
from repro.serving.scheduler import (ContinuousEngine, Request, RequestState,
                                     count_while_loops, poisson_trace)

__all__ = ["ServeEngine", "ContinuousEngine", "Request", "RequestState",
           "PageAllocator", "count_while_loops", "poisson_trace"]
