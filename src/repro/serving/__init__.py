"""Serving — ServeEngine decode loop with scan-based top-p sampling."""
