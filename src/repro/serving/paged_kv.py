"""Paged KV cache for continuous batching (tentpole of the serving subsystem).

The dense serving path allocates every row a rectangular ``(max_len, K, D)``
cache whether the request uses it or not.  Here the time axis is broken into
fixed ``page_size`` blocks drawn from a shared physical pool:

  * per layer, one ``(n_pages, page_size, K, D)`` pool for k and one for v;
  * per row, a ``(n_blocks,)`` int32 **page table** mapping logical block
    ``t // page_size`` to a pool page (the ``SegmentedBatch`` CSR offsets of
    PR 4, specialised to fixed-size segments);
  * a free-list allocator that picks the lowest free page ids with the
    paper's ``compress`` operator over the free mask — allocation is itself
    a §5 scan.

Page id 0 is **reserved scratch**: it is never handed out, unassigned page-
table entries point at it, and idle rows of the decode batch write their
(discarded) k/v there without clobbering live pages.

The paged layout is a *layout*, not a different attention: gathering a row's
pages back along time reproduces the dense ``(B, T, K, D)`` view, so for
equal attention length T paged and dense decode are bitwise identical
(dispatch-contract rule 11; ``gather_dense`` + the parity tests pin it).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guards
from repro.core.primitives import compress


def pages_needed(tokens: int, page_size: int) -> int:
    """Number of ``page_size`` blocks covering ``tokens`` positions."""
    return -(-tokens // page_size)


def _is_kv(node) -> bool:
    return isinstance(node, dict) and set(node) == {"k", "v"}


def _is_paged(node) -> bool:
    return isinstance(node, dict) and set(node) == {"k", "v", "pages"}


class PageAllocator:
    """Host-side free list over the physical page pool.

    The free mask lives on the host (allocation is control-plane work between
    scheduler ticks), but page selection runs the paper's ``compress``: pack
    the free page ids left and take the first ``n`` — lowest-id-first, so
    replays are deterministic and pool usage is dense.
    """

    def __init__(self, n_pages: int, *, method: str = "auto"):
        n_pages = guards.validate_positive(n_pages, name="n_pages",
                                           op="PageAllocator")
        if n_pages < 2:
            raise ValueError("PageAllocator: n_pages must be >= 2 (page 0 is "
                             "the reserved scratch page)")
        self.n_pages = n_pages
        self.method = method
        self.free = np.ones(n_pages, dtype=bool)
        self.free[0] = False                      # reserved scratch page
        self.peak_in_use = 0

    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the reserved scratch page)."""
        return self.n_pages - 1

    @property
    def in_use(self) -> int:
        return self.capacity - int(self.free.sum())

    def alloc(self, n: int) -> Optional[np.ndarray]:
        """Take the ``n`` lowest free page ids, or None if they don't fit."""
        n = guards.validate_positive(n, name="n", op="PageAllocator.alloc")
        ids, count = compress(jnp.arange(self.n_pages, dtype=jnp.int32),
                              jnp.asarray(self.free), method=self.method)
        if int(count) < n:
            return None
        taken = np.asarray(ids)[:n].copy()
        self.free[taken] = False
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return taken

    def release(self, ids) -> None:
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0:
            return
        if np.any(ids <= 0) or np.any(ids >= self.n_pages):
            raise ValueError(f"PageAllocator.release: page ids {ids.tolist()} "
                             f"outside the allocatable range "
                             f"[1, {self.n_pages})")
        if np.any(self.free[ids]):
            raise ValueError("PageAllocator.release: double free of pages "
                             f"{ids[self.free[ids]].tolist()}")
        self.free[ids] = True


def build_paged_caches(model, batch_size: int, n_pages: int, page_size: int,
                       n_blocks: int):
    """Paged decode caches matching ``model``'s dense cache structure.

    Every dense ``{"k", "v"}`` attention leaf of shape
    ``(*lead, B, clen, K, D)`` becomes ``{"k"/"v": (*lead, n_pages,
    page_size, K, D), "pages": (*lead, B, n_blocks)}`` — the page table is
    duplicated per layer so the whole cache flows through the layer-stack
    ``lax.scan`` unchanged.  Raises for models whose caches are not pure
    attention k/v (MLA latents, SSM/xLSTM states, cross-attention): the paged
    layout is defined for the attention time axis only.
    """
    tmpl = jax.eval_shape(lambda: model.empty_caches(batch_size, page_size))

    def walk(node, path):
        if _is_kv(node):
            k = node["k"]
            *lead, b, _, kh, hd = k.shape
            return {
                "k": jnp.zeros((*lead, n_pages, page_size, kh, hd), k.dtype),
                "v": jnp.zeros((*lead, n_pages, page_size, kh, hd),
                               node["v"].dtype),
                "pages": jnp.zeros((*lead, b, n_blocks), jnp.int32),
            }
        if isinstance(node, dict):
            return {key: walk(val, f"{path}/{key}") for key, val in
                    node.items()}
        raise ValueError(
            f"build_paged_caches: cache leaf at {path!r} is not an "
            "attention {k, v} pair — the paged KV layout supports "
            "attention-only decoders (dense/local/global/moe stacks)")

    return walk(tmpl, "caches")


def with_page_table(caches, row: int, page_ids) -> dict:
    """Functionally set row ``row``'s page table across every layer.

    ``page_ids``: 1-D int array of allocated pages for the row's leading
    blocks; trailing table entries reset to the scratch page 0.
    """
    page_ids = np.asarray(page_ids, dtype=np.int32)

    def walk(node):
        if _is_paged(node):
            nblk = node["pages"].shape[-1]
            table = np.zeros(nblk, np.int32)
            table[:page_ids.size] = page_ids
            return {**node,
                    "pages": node["pages"].at[..., row, :].set(
                        jnp.asarray(table))}
        return {key: walk(val) for key, val in node.items()}

    return walk(caches)


def clear_page_table(caches, row: int) -> dict:
    """Reset row ``row``'s page table to the scratch page (eviction)."""
    return with_page_table(caches, row, np.zeros(0, np.int32))


def insert_request(caches, dense_caches, row: int, page_ids) -> dict:
    """Scatter a request's dense prefill cache into its allocated pages.

    ``dense_caches``: the model's dense caches for the request alone
    (batch 1) with ``cache_len == len(page_ids) * page_size``; leaf shapes
    ``(*lead, 1, m*page_size, K, D)``.  Also installs the row's page table.
    """
    page_ids = np.asarray(page_ids, dtype=np.int32)
    ids = jnp.asarray(page_ids)

    def walk(pn, dn):
        if _is_paged(pn):
            ps = pn["k"].shape[-3]
            out = {"pages": pn["pages"]}
            for name in ("k", "v"):
                leaf = dn[name]
                *lead, _, t, kh, hd = leaf.shape
                if t != page_ids.size * ps:
                    raise ValueError(
                        f"insert_request: dense cache length {t} != "
                        f"{page_ids.size} pages x page_size {ps}")
                blocks = leaf.reshape(*lead, page_ids.size, ps, kh, hd)
                out[name] = pn[name].at[..., ids, :, :, :].set(
                    blocks.astype(pn[name].dtype))
            return out
        return {key: walk(pn[key], dn[key]) for key in pn}

    return with_page_table(walk(caches, dense_caches), row, page_ids)


def gather_dense(caches) -> dict:
    """Materialise the dense ``(B, n_blocks*page_size, K, D)`` view.

    Debug/parity helper: the gathered view is exactly what
    ``attn_decode_paged`` attends over, so comparing it against a dense-path
    cache is the rule-11 layout-parity check.
    """

    def gather(pool, pages):
        lead = pages.shape[:-2]
        pl = pool.reshape((-1,) + pool.shape[len(lead):])
        pg = pages.reshape((-1,) + pages.shape[len(lead):])
        out = jax.vmap(lambda p, t: p[t])(pl, pg)   # (lead*, B, nblk, ps, K, D)
        b, nblk, ps = out.shape[1], out.shape[2], out.shape[3]
        return out.reshape(lead + (b, nblk * ps) + out.shape[4:])

    def walk(node):
        if _is_paged(node):
            return {"k": gather(node["k"], node["pages"]),
                    "v": gather(node["v"], node["pages"])}
        return {key: walk(val) for key, val in node.items()}

    return walk(caches)
