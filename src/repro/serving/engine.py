"""Serving engine: batched prefill + decode with KV caches and the paper's
scan-based top-p (nucleus) sampler wired into the decode step (paper §5/§6.5 —
radix sort + prefix sum + inverse-transform sample, all on the matmul scan).
``sampler="topp_segmented"`` routes the same operator through the segmented
subsystem: the batch's logit rows become segments of one packed array, so a
ragged decode batch (rows of different active vocab slices, via
``sample_packed``) top-p samples in one launch without padding.
``scan_method=`` overrides the model config's scan method, so stateful decode
(the SSM/mLSTM linear-recurrence state updates, which route through
``repro.core.linrec.linear_scan``) can pick the fused kernel or blocked
pipeline without rebuilding the config by hand."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guards
from repro.core.dist_ops import dist_top_p_sample
from repro.core.primitives import METHODS, top_p_sample
from repro.core.segmented import SegmentedBatch, segment_top_p_sample
from repro.models.model import build_model
from repro.utils.sharding import use_mesh


class ServeEngine:
    SAMPLERS = ("greedy", "topp_auto", "topp_scan", "topp_kernel",
                "topp_blocked", "topp_segmented", "topp_sharded", "topp_xla")

    def __init__(self, cfg, params, *, mesh=None, max_len: int = 512,
                 top_p: float = 0.9, temperature: float = 1.0,
                 sampler: str = "topp_scan", bits_per_pass: int = 4,
                 scan_method: Optional[str] = None):
        sampler = guards.validate_choice(sampler, self.SAMPLERS,
                                         name="sampler", op="ServeEngine")
        # eager: fail at construction, not in jit
        bits_per_pass = guards.validate_bits_per_pass(bits_per_pass,
                                                      op="ServeEngine")
        guards.validate_probability(top_p, name="top_p", op="ServeEngine")
        guards.validate_temperature(temperature, op="ServeEngine")
        max_len = guards.validate_positive(max_len, name="max_len",
                                           op="ServeEngine")
        if scan_method is not None:
            if scan_method != "auto" and scan_method not in METHODS:
                raise ValueError(f"unknown scan_method {scan_method!r}; "
                                 f"expected one of {METHODS + ('auto',)}")
            cfg = dataclasses.replace(cfg, scan_method=scan_method)
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.max_len = max_len
        self.top_p = top_p
        self.temperature = temperature
        self.sampler = sampler
        # radix-2^k width of the sampler's sort passes: 4 -> the decode-path
        # bf16 key sort runs 4 radix-16 passes instead of 16 binary splits.
        self.bits_per_pass = bits_per_pass
        self.model = build_model(cfg)
        self._prefill = jax.jit(self._prefill_impl)
        if guards.checks_enabled():
            # checkified decode: staged guard_check assertions (pos < max_len)
            # fire as JaxRuntimeError.  checkify does not compose with donated
            # buffers, so this path re-uses the cache allocation instead.
            from jax.experimental import checkify
            cdec = jax.jit(checkify.checkify(self._decode_impl,
                                             errors=checkify.user_checks))

            def _decode_checked(params, caches, tok, pos, key):
                err, out = cdec(params, caches, tok, pos, key)
                err.throw()
                return out

            self._decode = _decode_checked
        else:
            self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))

    # ---- sampling (the paper's operator) ----
    def _sample(self, logits, key):
        """samplers: greedy | topp_auto (method from the tuning table) |
        topp_scan (matmul scans) | topp_kernel (fused Pallas radix passes +
        one-launch sampling tail) | topp_blocked (scans on the §4 blocked
        pipeline) | topp_segmented (rows packed as segments of one array,
        sampled by the segmented subsystem) | topp_sharded (model-parallel
        vocab: the distributed sampler over the mesh's "model" axis; on a
        mesh without that axis, or none at all, it degrades to the local
        matmul sampler — the same operator topp_scan runs) | topp_xla
        (baseline)."""
        if self.sampler == "greedy":
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if self.sampler == "topp_sharded":
            if (self.mesh is not None and "model" in self.mesh.shape
                    and self.mesh.shape["model"] > 1):
                return dist_top_p_sample(
                    logits, key, self.mesh, "model", p=self.top_p,
                    temperature=self.temperature, method="matmul",
                    bits_per_pass=self.bits_per_pass).astype(jnp.int32)
            return top_p_sample(logits, key, p=self.top_p,
                                temperature=self.temperature, method="matmul",
                                sort_method="radix",
                                bits_per_pass=self.bits_per_pass
                                ).astype(jnp.int32)
        if self.sampler == "topp_segmented":
            b, v = logits.shape
            offsets = jnp.arange(b + 1, dtype=jnp.int32) * v
            return segment_top_p_sample(
                logits.reshape(b * v), offsets, key, p=self.top_p,
                temperature=self.temperature,
                bits_per_pass=self.bits_per_pass).astype(jnp.int32)
        method = {"topp_kernel": "kernel", "topp_blocked": "blocked",
                  "topp_auto": "auto"}.get(self.sampler, "matmul")
        sort_method = "xla" if self.sampler == "topp_xla" else "radix"
        return top_p_sample(logits, key, p=self.top_p,
                            temperature=self.temperature, method=method,
                            sort_method=sort_method,
                            bits_per_pass=self.bits_per_pass).astype(jnp.int32)

    def sample_packed(self, packed: SegmentedBatch, key) -> jnp.ndarray:
        """Top-p sample every segment of a packed ragged logits batch at once.

        ``packed``: a :class:`~repro.core.segmented.SegmentedBatch` whose
        segments are per-request logit slices (rows may have different
        lengths — e.g. per-request vocabulary masks in continuous batching).
        Returns one int32 segment-local token id per segment, in one launch;
        no padding to the longest row is performed.
        """
        return segment_top_p_sample(
            packed.values, packed.offsets, key, p=self.top_p,
            temperature=self.temperature,
            bits_per_pass=self.bits_per_pass).astype(jnp.int32)

    def _prefill_impl(self, params, batch, key):
        with use_mesh(self.mesh):
            last_logits, caches = self.model.prefill(params, batch,
                                                     cache_len=self.max_len)
            tok = self._sample(last_logits, key)
            return tok, caches

    def _decode_impl(self, params, caches, tok, pos, key):
        with use_mesh(self.mesh):
            guards.guard_check(lambda: pos < self.max_len,
                               "decode: pos must stay below max_len (the KV "
                               "cache budget) — raise max_len= at engine "
                               "construction")
            logits, caches = self.model.decode_step(params, tok[:, None],
                                                    caches, pos)
            new_tok = self._sample(logits, key)
            return new_tok, caches

    def generate(self, batch: Dict, max_new_tokens: int, key, *,
                 eos_id: Optional[int] = None,
                 sync_every: int = 8) -> jnp.ndarray:
        """Generate up to ``max_new_tokens`` tokens per row.

        ``batch``: model inputs incl. ``"tokens"`` (B, S).  Returns
        ``(B, new_tokens)`` int32 — ``new_tokens == max_new_tokens``, or
        fewer when ``eos_id`` is set and every row finished early
        (``max_new_tokens == 0`` returns an empty ``(B, 0)`` array without
        touching the model).

        Args:
            batch: Model inputs including ``"tokens"`` of shape (B, S).
            max_new_tokens: Number of tokens to decode (>= 0).
            key: PRNG key for the samplers.
            eos_id: Optional end-of-sequence token id.  Rows that emit it
                keep emitting it (their KV entries are not advanced with new
                content), and decoding stops once every row has finished.
            sync_every: How often (in tokens) the all-rows-done mask is
                synced to the host when ``eos_id`` is set — the scheduler
                tick.  The mask itself stays on device; a larger tick means
                fewer host round-trips but up to ``sync_every - 1`` wasted
                decode steps after the last row finishes.  The returned
                tokens are bit-identical for every ``sync_every >= 1``
                (over-decoded trailing columns are trimmed).

        Raises:
            ValueError: If ``max_new_tokens`` is negative, ``sync_every``
                is not positive, or the request does not fit the KV cache
                budget (``prompt_len + cache_offset + max_new_tokens >
                max_len``).
        """
        tokens = batch["tokens"]
        b, s = tokens.shape
        off = self.cfg.n_img_tokens if self.cfg.family == "vlm" else 0
        if max_new_tokens < 0:
            raise ValueError(
                f"generate: max_new_tokens must be >= 0, got {max_new_tokens}")
        sync_every = guards.validate_positive(sync_every, name="sync_every",
                                              op="generate")
        if s + off + max_new_tokens > self.max_len:
            raise ValueError(
                f"generate: prompt ({s} tokens) + cache offset ({off}) + "
                f"max_new_tokens ({max_new_tokens}) = "
                f"{s + off + max_new_tokens} overflows the KV cache budget "
                f"(max_len={self.max_len}); raise max_len= at engine "
                "construction or shorten the request")
        if max_new_tokens == 0:
            return jnp.zeros((b, 0), jnp.int32)
        key, k0 = jax.random.split(key)
        tok, caches = self._prefill(self.params, batch, k0)
        # the done mask lives on device; only jnp.all(done) crosses to the
        # host, and only once per sync_every-token scheduler tick
        done = (tok == eos_id) if eos_id is not None else None
        out = [tok]
        pos = s + off
        for i in range(max_new_tokens - 1):
            if (done is not None and i % sync_every == 0
                    and bool(jax.device_get(jnp.all(done)))):
                break  # every row emitted eos_id — stop early
            key, k = jax.random.split(key)
            tok, caches = self._decode(self.params, caches, tok,
                                       jnp.asarray(pos + i, jnp.int32), k)
            if done is not None:
                tok = jnp.where(done, jnp.asarray(eos_id, tok.dtype), tok)
                done = done | (tok == eos_id)
            out.append(tok)
        res = jnp.stack(out, axis=1)
        if done is not None and res.shape[1] > 1:
            # trim columns decoded past the point where every row had
            # finished — reproduces per-token early exit bit-identically
            # whatever the tick size
            col_done = np.logical_or.accumulate(
                np.asarray(res == eos_id), axis=1).all(axis=0)
            hits = np.nonzero(col_done)[0]
            if hits.size:
                res = res[:, :int(hits[0]) + 1]
        return res
