"""Continuous-batching scheduler: FCFS admission, paged KV, in-graph decode.

``ContinuousEngine.run(requests)`` serves a ragged trace of variable-length
requests through a fixed decode batch of ``max_batch`` rows:

  * **admission** — strict FCFS over arrived requests; a request is admitted
    when a batch row is free and the :class:`~repro.serving.paged_kv.
    PageAllocator` can cover ``prompt + max_new_tokens`` positions (the head
    of the queue never gets bypassed, so admission order is reproducible
    under budget pressure);
  * **prefill** — each admitted request prefills alone (batch 1) and its
    dense cache is scattered into its allocated pages;
  * **decode** — all running rows step together through ``decode_n``, one
    ``lax.while_loop`` staging up to ``tick_tokens`` model steps with the
    all-rows-done predicate *inside* the graph — one host sync per tick, not
    per token;
  * **eviction** — rows that emit their eos or exhaust their budget release
    their pages at the tick boundary and the row is refilled FCFS.

Exact-stream contract (the acceptance bar): a request served continuously
emits the byte-for-byte token stream :class:`~repro.serving.engine.
ServeEngine` ``generate`` emits for it alone, given the same sampler, the
same per-request PRNG key, and a dense ``max_len`` equal to this engine's
``n_blocks * page_size`` (equal attention length — rule 11).  This works
because batch rows are computationally independent, the paged gather
reproduces the dense cache view bitwise, and each row carries its own PRNG
chain split exactly like the solo loop (``key, k = split(key)`` per token).

Time is virtual: the clock advances one unit per decode iteration, so
arrival traces, latencies, and the whole schedule replay deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import guards
from repro.core.dist_ops import dist_top_p_sample
from repro.core.primitives import top_p_sample
from repro.models.model import build_model
from repro.serving import paged_kv
from repro.utils.sharding import use_mesh


@dataclasses.dataclass
class Request:
    """One serving request.

    ``key`` is the request's own PRNG key (uint32 ``(2,)``, e.g.
    ``jax.random.PRNGKey(i)``) — the same key handed to a solo
    ``ServeEngine.generate`` call reproduces the same stream.
    ``arrival_step`` is in virtual decode steps.
    """
    rid: str
    tokens: np.ndarray
    max_new_tokens: int
    key: np.ndarray
    eos_id: Optional[int] = None
    arrival_step: int = 0


@dataclasses.dataclass
class RequestState:
    """Scheduler-side state of an admitted request."""
    request: Request
    slot: int
    page_ids: np.ndarray
    admit_step: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    finish_step: Optional[int] = None


def count_while_loops(jaxpr) -> int:
    """Count ``while`` equations in a (closed) jaxpr, nested ones included.

    The trace-only launch guard: ``decode_n`` must stage exactly one —
    multi-token decode is one ``lax.while_loop``, not per-token dispatch.
    """
    jx = getattr(jaxpr, "jaxpr", jaxpr)
    n = 0
    for eqn in jx.eqns:
        if eqn.primitive.name == "while":
            n += 1
        for val in eqn.params.values():
            for v in val if isinstance(val, (tuple, list)) else (val,):
                if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                    n += count_while_loops(v)
    return n


def poisson_trace(n_requests: int, *, rate: float, vocab_size: int, seed: int,
                  prompt_len=(4, 12), max_new=(2, 8),
                  eos_id: Optional[int] = None) -> List[Request]:
    """Synthetic Poisson arrival trace (deterministic in ``seed``).

    Inter-arrival gaps are exponential with mean ``1/rate`` (in virtual
    decode steps); prompt lengths and decode budgets are uniform over the
    given inclusive ranges.
    """
    guards.validate_positive(n_requests, name="n_requests", op="poisson_trace")
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(rng.exponential(1.0 / rate,
                                                  n_requests))).astype(int)
    reqs = []
    for i in range(n_requests):
        s = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        n = int(rng.integers(max_new[0], max_new[1] + 1))
        toks = rng.integers(0, vocab_size, size=s).astype(np.int32)
        reqs.append(Request(
            rid=f"req{i}", tokens=toks, max_new_tokens=n,
            key=np.asarray(jax.random.PRNGKey(seed * 7919 + i)),
            eos_id=eos_id, arrival_step=int(arrivals[i])))
    return reqs


class ContinuousEngine:
    """Continuous-batching engine over a paged KV cache.

    Restricted to attention-only decoder stacks (dense/local/global/moe
    layers) — the paged layout pages the attention time axis; recurrent
    state (SSM/xLSTM), MLA latents, and cross-attention caches have no
    page-table form here and are rejected at construction.
    """

    SAMPLERS = ("greedy", "topp_scan", "topp_sharded", "topp_xla")
    _KINDS = frozenset({"dense", "local", "global", "moe"})

    def __init__(self, cfg, params, *, mesh=None, max_batch: int = 4,
                 page_size: int = 8, n_pages: int = 64,
                 max_len: Optional[int] = None, top_p: float = 0.9,
                 temperature: float = 1.0, sampler: str = "greedy",
                 bits_per_pass: int = 4, tick_tokens: int = 8):
        op = "ContinuousEngine"
        self.sampler = guards.validate_choice(sampler, self.SAMPLERS,
                                              name="sampler", op=op)
        guards.validate_probability(top_p, name="top_p", op=op)
        guards.validate_temperature(temperature, op=op)
        self.bits_per_pass = guards.validate_bits_per_pass(bits_per_pass,
                                                           op=op)
        self.max_batch = guards.validate_positive(max_batch, name="max_batch",
                                                  op=op)
        self.page_size = guards.validate_positive(page_size, name="page_size",
                                                  op=op)
        self.tick_tokens = guards.validate_positive(tick_tokens,
                                                    name="tick_tokens", op=op)
        self.alloc = paged_kv.PageAllocator(n_pages)
        self.n_pages = self.alloc.n_pages
        if max_len is None:
            max_len = self.alloc.capacity * self.page_size
        self.max_len = guards.validate_positive(max_len, name="max_len", op=op)
        self.n_blocks = paged_kv.pages_needed(self.max_len, self.page_size)
        self.top_p = top_p
        self.temperature = temperature
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.model = build_model(cfg)
        kinds = set(getattr(self.model, "pattern", ()))
        if cfg.family not in ("decoder", "moe") or not kinds <= self._KINDS:
            raise ValueError(
                f"{op}: {cfg.name!r} (family={cfg.family!r}, "
                f"pattern={sorted(kinds)}) is not an attention-only decoder "
                "stack — the paged KV layout pages the attention time axis "
                "only; serve it with the dense ServeEngine instead")
        self.caches = paged_kv.build_paged_caches(
            self.model, self.max_batch, self.n_pages, self.page_size,
            self.n_blocks)
        self._prefill = jax.jit(self._prefill_impl, static_argnums=(3,))
        if guards.checks_enabled():
            # checkify does not compose with donated buffers (see ServeEngine)
            from jax.experimental import checkify
            cdec = jax.jit(checkify.checkify(self._decode_n_impl,
                                             errors=checkify.user_checks),
                           static_argnums=(8,))

            def _decode_checked(*args):
                err, out = cdec(*args)
                err.throw()
                return out

            self._decode_n = _decode_checked
        else:
            self._decode_n = jax.jit(self._decode_n_impl, donate_argnums=(1,),
                                     static_argnums=(8,))

    # ---- sampling: per-row key chains, same operators as ServeEngine ----
    def _sample_rows(self, logits, keys):
        """Sample one token per row, row ``r`` from ``keys[r]``.

        Each row runs the single-request sampler under ``vmap`` — bitwise
        what a solo ``ServeEngine._sample`` computes on that row with that
        key, which is what makes continuous streams replay solo ones.
        """
        if self.sampler == "greedy":
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if (self.sampler == "topp_sharded" and self.mesh is not None
                and "model" in self.mesh.shape
                and self.mesh.shape["model"] > 1):
            # shard_map does not vmap, so the per-row PRNG chains enter
            # through the sampler's u= override: one uniform per row from
            # that row's key — the same single draw (identical bits) a solo
            # ServeEngine sampler takes from it — then one batched
            # distributed call
            u = jax.vmap(
                lambda k: jax.random.uniform(k, (1,), jnp.float32))(keys)
            return dist_top_p_sample(
                logits, None, self.mesh, "model", p=self.top_p,
                temperature=self.temperature, method="matmul",
                bits_per_pass=self.bits_per_pass, u=u).astype(jnp.int32)
        sort_method = "xla" if self.sampler == "topp_xla" else "radix"

        def one(lg, k):
            return top_p_sample(lg[None], k, p=self.top_p,
                                temperature=self.temperature, method="matmul",
                                sort_method=sort_method,
                                bits_per_pass=self.bits_per_pass)[0]

        return jax.vmap(one)(logits, keys).astype(jnp.int32)

    # ---- prefill (one request alone, batch 1) ----
    def _prefill_impl(self, params, tokens, key, cache_len):
        with use_mesh(self.mesh):
            last_logits, caches = self.model.prefill(params,
                                                     {"tokens": tokens},
                                                     cache_len=cache_len)
            tok = self._sample_rows(last_logits, key[None, :])
            return tok[0], caches

    # ---- decode_n: the in-graph multi-token loop ----
    def _decode_n_impl(self, params, caches, tok, pos, keys, done, rem, eos,
                       n_steps):
        """Up to ``n_steps`` decode iterations in one ``lax.while_loop``.

        Carry per row: current token, write position, PRNG chain key, done
        flag, remaining token budget.  The loop exits early on-device when
        every row is done — no per-token host syncs.  ``eos`` is per-row
        (-1 = no eos).  Done rows keep stepping (their position frozen, so
        they only rewrite their own last slot / the scratch page) and their
        emitted slots are padded; callers harvest ``out[r, :emitted]`` via
        the returned ``rem``.
        """
        with use_mesh(self.mesh):
            cap = self.n_blocks * self.page_size
            guards.guard_check(
                lambda: jnp.all(jnp.where(done, 0,
                                          pos + jnp.minimum(rem, n_steps))
                                <= cap),
                "decode_n: a row's write positions would overrun its page "
                "budget (n_blocks * page_size) — admission must bound "
                "prompt + max_new_tokens by max_len")
            b = tok.shape[0]
            out0 = jnp.zeros((b, n_steps), jnp.int32)

            def cond(carry):
                i, done = carry[0], carry[6]
                return (i < n_steps) & jnp.logical_not(jnp.all(done))

            def body(carry):
                i, out, tok, caches, pos, keys, done, rem = carry
                ks = jax.vmap(jax.random.split)(keys)   # (B, 2, 2)
                keys2, kstep = ks[:, 0], ks[:, 1]
                logits, caches = self.model.decode_step(params, tok[:, None],
                                                        caches, pos)
                new = self._sample_rows(logits, kstep)
                new = jnp.where(done, jnp.maximum(eos, 0), new)
                out = out.at[:, i].set(new)
                rem2 = jnp.where(done, rem, rem - 1)
                done2 = done | ((new == eos) & (eos >= 0)) | (rem2 <= 0)
                pos2 = jnp.where(done2, pos, pos + 1)
                return (i + 1, out, new, caches, pos2, keys2, done2, rem2)

            carry = (jnp.zeros((), jnp.int32), out0, tok, caches, pos, keys,
                     done, rem)
            i, out, tok, caches, pos, keys, done, rem = jax.lax.while_loop(
                cond, body, carry)
            return out, i, tok, caches, pos, keys, done, rem

    def decode_n_jaxpr(self, n_steps: Optional[int] = None):
        """Trace-only: the jaxpr ``decode_n`` stages (for launch guards)."""
        n = n_steps or self.tick_tokens
        b = self.max_batch
        return jax.make_jaxpr(
            lambda p, c, t, ps, k, d, r, e:
            self._decode_n_impl(p, c, t, ps, k, d, r, e, n))(
                self.params, self.caches,
                jnp.zeros((b,), jnp.int32), jnp.zeros((b,), jnp.int32),
                jnp.zeros((b, 2), jnp.uint32), jnp.zeros((b,), bool),
                jnp.ones((b,), jnp.int32), jnp.full((b,), -1, jnp.int32))

    # ---- request validation (eager: fail before touching the model) ----
    def _validate(self, req: Request) -> np.ndarray:
        toks = np.asarray(req.tokens, np.int32)
        if toks.ndim != 1 or toks.size == 0:
            raise ValueError(f"run: request {req.rid!r} has a zero-length or "
                             f"non-1D prompt (shape {toks.shape}) — every "
                             "request needs at least one prompt token")
        if req.max_new_tokens < 1:
            raise ValueError(f"run: request {req.rid!r} asks for "
                             f"{req.max_new_tokens} tokens; continuous "
                             "batching serves requests with "
                             "max_new_tokens >= 1")
        total = toks.size + req.max_new_tokens
        if total > self.max_len:
            raise ValueError(
                f"run: request {req.rid!r} needs {total} positions "
                f"(prompt {toks.size} + max_new_tokens "
                f"{req.max_new_tokens}) > max_len={self.max_len} — it can "
                "never be admitted; raise max_len/n_pages or shorten it")
        if paged_kv.pages_needed(total, self.page_size) > self.alloc.capacity:
            raise ValueError(
                f"run: request {req.rid!r} needs "
                f"{paged_kv.pages_needed(total, self.page_size)} pages > "
                f"pool capacity {self.alloc.capacity}")
        return toks

    # ---- the driver ----
    def run(self, requests: Sequence[Request], *,
            max_ticks: int = 100_000) -> Dict:
        """Serve ``requests`` to completion; returns streams + schedule stats.

        One host sync per decode tick (plus one per admission).  Replaying
        the same trace on the same engine yields the identical result dict
        (virtual-time clock, FCFS admission, lowest-page-first allocation).
        """
        reqs = [(self._validate(r), r) for r in requests]
        order = sorted(range(len(reqs)),
                       key=lambda i: (reqs[i][1].arrival_step, i))
        queue = [reqs[i] for i in order]

        b = self.max_batch
        # reset page tables: stale tables from a previous run must not alias
        # freshly allocated pages
        for r in range(b):
            self.caches = paged_kv.clear_page_table(self.caches, r)
        self.alloc = paged_kv.PageAllocator(self.n_pages)

        slots: List[Optional[RequestState]] = [None] * b
        tok = np.zeros(b, np.int32)
        pos = np.zeros(b, np.int32)
        keys = np.zeros((b, 2), np.uint32)
        done = np.ones(b, bool)                 # idle rows count as done
        rem = np.zeros(b, np.int32)
        eos = np.full(b, -1, np.int32)
        step = 0
        ticks = 0
        finished: List[RequestState] = []

        def admit(toks_np, req):
            total = toks_np.size + req.max_new_tokens
            m = paged_kv.pages_needed(total, self.page_size)
            slot = next((i for i, s in enumerate(slots) if s is None), None)
            if slot is None:
                return False
            pages = self.alloc.alloc(m)
            if pages is None:
                return False
            key = jnp.asarray(np.asarray(req.key), jnp.uint32)
            key, k0 = jax.random.split(key)
            t0, dense = self._prefill(self.params,
                                      jnp.asarray(toks_np)[None, :], k0,
                                      m * self.page_size)
            self.caches = paged_kv.insert_request(self.caches, dense, slot,
                                                  pages)
            st = RequestState(request=req, slot=slot, page_ids=pages,
                              admit_step=step, tokens=[int(t0)])
            e = -1 if req.eos_id is None else int(req.eos_id)
            fin = ((e >= 0 and st.tokens[0] == e)
                   or req.max_new_tokens <= 1)
            if fin:
                st.finish_step = step
                self.alloc.release(pages)
                self.caches = paged_kv.clear_page_table(self.caches, slot)
                finished.append(st)
                return True
            slots[slot] = st
            tok[slot] = st.tokens[0]
            pos[slot] = toks_np.size
            keys[slot] = np.asarray(key)
            done[slot] = False
            rem[slot] = req.max_new_tokens - 1
            eos[slot] = e
            return True

        while queue or any(s is not None for s in slots):
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError(f"run: exceeded max_ticks={max_ticks} — "
                                   "scheduler is not draining")
            # strict FCFS admission of arrived requests
            while queue and queue[0][1].arrival_step <= step:
                if not admit(*queue[0]):
                    break
                queue.pop(0)
            if all(s is None for s in slots):
                if queue:       # idle: fast-forward to the next arrival
                    step = max(step, queue[0][1].arrival_step)
                continue

            rem_before = rem.copy()
            out, nsteps, tok_d, self.caches, pos_d, keys_d, done_d, rem_d = \
                self._decode_n(self.params, self.caches, jnp.asarray(tok),
                               jnp.asarray(pos), jnp.asarray(keys),
                               jnp.asarray(done), jnp.asarray(rem),
                               jnp.asarray(eos), self.tick_tokens)
            # ONE host sync for the whole tick (np.array: device_get views
            # can be read-only, and admission mutates these in place)
            out, nsteps, tok, pos, keys, done, rem = [
                np.array(x) for x in jax.device_get(
                    (out, nsteps, tok_d, pos_d, keys_d, done_d, rem_d))]
            base = step
            step += int(nsteps)
            for r, st in enumerate(slots):
                if st is None:
                    continue
                emitted = int(rem_before[r] - rem[r])
                st.tokens.extend(int(t) for t in out[r, :emitted])
                if done[r]:
                    st.finish_step = base + emitted
                    self.alloc.release(st.page_ids)
                    self.caches = paged_kv.clear_page_table(self.caches, r)
                    finished.append(st)
                    slots[r] = None

        finished.sort(key=lambda st: (st.finish_step, st.request.rid))
        total_tokens = sum(len(st.tokens) for st in finished)
        return {
            "streams": {st.request.rid: np.asarray(st.tokens, np.int32)
                        for st in finished},
            "requests": {st.request.rid: {
                "arrival_step": st.request.arrival_step,
                "admit_step": st.admit_step,
                "finish_step": st.finish_step,
                "n_tokens": len(st.tokens),
                "latency_steps": st.finish_step - st.request.arrival_step,
                "per_token_latency_steps":
                    (st.finish_step - st.request.arrival_step)
                    / max(len(st.tokens), 1),
            } for st in finished},
            "stats": {
                "steps": step,
                "ticks": ticks,
                "total_tokens": total_tokens,
                "reqs": len(finished),
                "peak_pages": self.alloc.peak_in_use,
                "pool_capacity": self.alloc.capacity,
                "peak_util": self.alloc.peak_in_use / self.alloc.capacity,
            },
        }
