"""ulp-accuracy oracle for the precision axis (``precision="compensated"``).

The precision contract of :mod:`repro.core.precision` is stated in **fp32 ulps
at the conditioning scale**: an error of ``k`` ulps means the result differs
from the fp64 sequential reference by at most ``k`` spacings of fp32 *at the
magnitude the scan actually accumulated through*, not at the magnitude of the
(possibly cancelled-to-zero) output.  Measuring at the output's own magnitude
would let benign cancellation — ``cumsum`` of a ±-balanced array passing
through zero — blow the metric up unboundedly for *every* float method,
including the fp32 ``"vector"`` reference the contract is stated against.

The conditioning scales (all fp64, sequential, order-faithful):

* scan / cumsum:        ``scale_i = Σ_{j<=i} |x_j|``
* linear recurrence:    ``scale_i = |a_i|·scale_{i-1} + |b_i|``
* segmented scan:       the *global* (unrestarted) scan scale — the method
  table includes the subtract-the-segment-start formulation
  (``segmented._segment_scan_unfused``), whose rounding error lives at the
  packed global prefix scale, so that is the scale the contract shares
  across methods (the fused kernels' per-segment errors are only smaller).

Per-precision bounds are ``ULP_COEFF[precision] · √n`` — the random-walk
growth of rounding error with accumulation length.  The coefficients were set
by measuring the hypothesis sweeps in ``tests/test_precision.py`` across
methods, tile sizes and adversarial value distributions, then adding margin;
``"compensated"`` is required to stay within a small constant factor of
``"highest"`` (the documented recovery claim), while ``"fast"`` (bf16, ~8
significand bits) is documented, loose, and ~2^16 wider.

Two documented provisos on the per-element bound:

* every precision assumes inputs in fp32's *normal* range: XLA flushes
  subnormal operands to zero in matmul **and** in the plain multiplies the
  split's ``ldexp`` scaling lowers to, so subnormal inputs flush to exact
  zeros on every engine path and precision alike (deterministically — no
  nan/inf; ``tests/test_precision.py`` pins the flush down).  Normal-range
  inputs arbitrarily close to ``tiny`` are fine: the per-slice scaling is an
  exact power-of-two move, so the bound holds unchanged at exponent extremes.
* ``"compensated"`` assumes the dynamic range *within one contraction slice*
  (a tile row) fits the split's ~2^35 window; elements smaller than that
  relative to their slice max are below fp32 significance at the slice scale
  and are dropped, so for such inputs the bound is only guaranteed at the
  end-of-scan conditioning scale (``scale[..., -1:]``), not per element.

Everything here is plain numpy so the oracle itself cannot inherit a JAX
rounding quirk; ``benchmarks/run.py precision`` reuses it for the ``max_ulp``
derived column that CI gates against ``BENCH_precision.json``.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "ULP_COEFF", "ulp_bound", "ulp_error", "max_ulp",
    "scan_ref", "scan_scale", "linrec_ref", "linrec_scale",
    "segment_scan_ref", "segment_scan_scale",
]

# bound = ULP_COEFF[precision] * sqrt(n) fp32 ulps at the conditioning scale.
# "highest" and "compensated" share the small-constant regime (the recovery
# claim); "fast" is bf16's 16-bit-wider spacing plus the same √n growth.
ULP_COEFF = {
    "highest": 8.0,
    "compensated": 16.0,
    "fast": 8.0 * 2.0 ** 16,
}


def ulp_bound(precision: str, n: int) -> float:
    """The documented max-ulp bound for one op call of length ``n``.

    Args:
        precision: One of ``ULP_COEFF``.
        n: Scanned length (accumulation count).

    Returns:
        The bound in fp32 ulps at the conditioning scale.

    Example:
        >>> ulp_bound("highest", 4) == 16.0
        True
    """
    return ULP_COEFF[precision] * float(np.sqrt(max(n, 1)))


def _spacing_at(scale: np.ndarray) -> np.ndarray:
    """fp32 ulp size at magnitude ``scale`` (clamped to the normal range)."""
    s = np.abs(np.asarray(scale, np.float64))
    tiny = float(np.finfo(np.float32).tiny)
    huge = float(np.finfo(np.float32).max)
    s = np.clip(s, tiny, huge)
    return np.spacing(s.astype(np.float32)).astype(np.float64)


def ulp_error(got, ref, scale) -> np.ndarray:
    """Elementwise error of ``got`` vs ``ref`` in fp32 ulps at ``scale``.

    Non-finite reference elements are compared structurally: a matching
    ``inf`` (same sign) or ``nan`` scores 0 ulps, a mismatch scores ``inf`` —
    the compensated split's contract is that non-finites propagate exactly as
    through an fp32 contraction.

    Args:
        got: Computed values (any float dtype; cast to fp64).
        ref: fp64 reference values, same shape.
        scale: fp64 conditioning scale, same shape (see module docstring).

    Returns:
        fp64 array of ulp counts (``>= 0``).

    Example:
        >>> import numpy as np
        >>> ref = np.asarray([1.0, np.inf])
        >>> got = np.asarray([1.0 + np.spacing(np.float32(1.0)), np.inf])
        >>> ulp_error(got, ref, np.asarray([1.0, 1.0])).round(2).tolist()
        [1.0, 0.0]
    """
    got = np.asarray(got, np.float64)
    ref = np.asarray(ref, np.float64)
    err = np.abs(got - ref) / _spacing_at(scale)
    bad = ~np.isfinite(ref)
    if bad.any():
        same = (np.isnan(ref) & np.isnan(got)) | (ref == got)
        err = np.where(bad, np.where(same, 0.0, np.inf), err)
    return err


def max_ulp(got, ref, scale) -> float:
    """``float(np.max(ulp_error(...)))`` — 0.0 for empty inputs."""
    e = ulp_error(got, ref, scale)
    return float(np.max(e)) if e.size else 0.0


# ---------------------------------------------------------------------------
# fp64 sequential references + conditioning scales
# ---------------------------------------------------------------------------


def scan_ref(x) -> np.ndarray:
    """fp64 inclusive prefix sum over the last axis (the cumsum oracle)."""
    return np.cumsum(np.asarray(x, np.float64), axis=-1)


def scan_scale(x) -> np.ndarray:
    """Conditioning scale of :func:`scan_ref`: prefix sums of ``|x|``."""
    return np.cumsum(np.abs(np.asarray(x, np.float64)), axis=-1)


def linrec_ref(a, b) -> np.ndarray:
    """fp64 sequential ``y_t = a_t * y_{t-1} + b_t`` over the last axis."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    out = np.empty_like(b)
    state = np.zeros(b.shape[:-1], np.float64)
    for i in range(b.shape[-1]):
        state = a[..., i] * state + b[..., i]
        out[..., i] = state
    return out


def linrec_scale(a, b) -> np.ndarray:
    """Conditioning scale of :func:`linrec_ref`: the ``|a|, |b|`` recurrence."""
    return linrec_ref(np.abs(np.asarray(a, np.float64)),
                      np.abs(np.asarray(b, np.float64)))


def segment_scan_ref(x, offsets) -> np.ndarray:
    """fp64 per-segment inclusive prefix sums of packed 1-D ``x``."""
    x = np.asarray(x, np.float64)
    off = np.asarray(offsets)
    out = np.empty_like(x)
    for i in range(off.shape[0] - 1):
        out[off[i]:off[i + 1]] = np.cumsum(x[off[i]:off[i + 1]])
    return out


def segment_scan_scale(x, offsets) -> np.ndarray:
    """Conditioning scale of :func:`segment_scan_ref`: *global* ``|x|`` prefix.

    Deliberately not restarted at boundaries — see the module docstring: the
    unfused (matmul/vector) segmented formulation subtracts the unsegmented
    scan at each segment start, so its rounding error is at the packed global
    prefix scale and the shared contract must be stated there.
    """
    del offsets  # the scale is offset-independent by design (see docstring)
    return scan_scale(x)
