"""Summarize dry-run JSON records into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.analysis.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = ["whisper-small", "gemma2-2b", "qwen3-4b", "minicpm3-4b",
              "llama3-8b", "paligemma-3b", "zamba2-1.2b",
              "llama4-scout-17b-16e", "deepseek-moe-16b", "xlstm-350m"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(d, prefer: str = "experiments/final"):
    """Load records; cells re-measured with the final code (``prefer`` dir)
    override the originals."""
    by_cell = {}
    for src in (d, prefer):
        if not os.path.isdir(src):
            continue
        for f in glob.glob(os.path.join(src, "dryrun_*.json")):
            with open(f) as fh:
                r = json.load(fh)
            if r.get("status") == "fail" and (r["arch"], r["shape"],
                                              r["mesh"]) in by_cell:
                continue
            by_cell[(r["arch"], r["shape"], r["mesh"])] = r
    recs = list(by_cell.values())
    def key(r):
        return (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER
                else 99, SHAPE_ORDER.index(r["shape"]), r["mesh"])
    return sorted(recs, key=key)


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}µs"


HBM_BW = 819e9
PEAK = 197e12


def derived_terms(r):
    """memory lower-bound term (arguments+outputs traffic — the XLA-CPU
    'bytes accessed' double-counts fusion-internal operands) + MFU at bound."""
    bpd = r["bytes_per_device"]
    mem_lb = (bpd["arguments"] + bpd["output"]) / HBM_BW
    ro = r["roofline"]
    step = max(ro["compute_s"], mem_lb, ro["collective_s"])
    ideal = r["model_flops_per_chip"] / PEAK
    terms = {"compute": ro["compute_s"], "memory(lb)": mem_lb,
             "collective": ro["collective_s"]}
    return mem_lb, max(terms, key=terms.get), (ideal / step if step else 0.0)


def table(recs, mesh):
    print(f"\n### Roofline — {mesh} pod mesh "
          f"({'2×16×16 = 512' if mesh == 'multi' else '16×16 = 256'} chips)\n")
    print("| arch | shape | compute | mem(hlo) | mem(lb) | collective | "
          "bottleneck | MFU@bound | MODEL/HLO | args GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                  f"skipped: {r['reason'][:40]} | — | — | — |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                  f"**FAIL** {r['error'][:38]} | — | — | — |")
            continue
        ro = r["roofline"]
        bpd = r["bytes_per_device"]
        mem_lb, bneck, mfu = derived_terms(r)
        print(f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} | "
              f"{fmt_s(ro['memory_s'])} | {fmt_s(mem_lb)} | "
              f"{fmt_s(ro['collective_s'])} | {bneck} | {mfu:.3f} | "
              f"{r['useful_fraction']:.2f} | "
              f"{bpd['arguments'] / 2**30:.2f} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="both")
    args = ap.parse_args()
    recs = load(args.dir)
    ok = sum(r["status"] == "ok" for r in recs)
    skip = sum(r["status"] == "skip" for r in recs)
    fail = len(recs) - ok - skip
    print(f"{len(recs)} records: {ok} ok, {skip} skipped (documented), "
          f"{fail} failed")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for m in meshes:
        table(recs, m)


if __name__ == "__main__":
    main()
