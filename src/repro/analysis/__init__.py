"""Analysis — roofline/report/collectives tooling and the fault harness."""
from repro.analysis.faults import (
    OUTCOMES, adversarial_params, classify, corrupt_offsets, inject_nonfinite,
)
