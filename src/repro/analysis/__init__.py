"""Analysis — roofline/report/collectives tooling over BENCH output."""
