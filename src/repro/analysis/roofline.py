"""Three-term roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

TPU v5e per-chip constants (the TARGET hardware; this container only compiles):
  peak bf16 compute 197 TFLOP/s · HBM 819 GB/s · ICI ~50 GB/s/link.

``cost_analysis()`` on the post-SPMD module is *per chip*; so
  compute  = flops / PEAK_FLOPS
  memory   = bytes_accessed / HBM_BW
  collective = effective wire bytes per chip / ICI_BW
equivalently HLO_global/(chips·peak) as in the assignment formulas.

Collective bytes come from parsing the post-SPMD HLO: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute op's
operand size (derived from the printed result shape and replica-group size), scaled
by the ring-traffic factor of the op kind.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = f32[64,64]{0,1} all-gather(%x), ... replica_groups={{0,1},..}
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> List[Dict]:
    """Per-collective: kind, result bytes (local), group size, wire bytes/chip."""
    out = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_part, dtype, dims, kind = m.group(1), m.group(2), m.group(3), m.group(4)
        if tuple_part:                       # tuple result: sum components
            rbytes = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(tuple_part))
        else:
            rbytes = _shape_bytes(dtype, dims)
        g = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mg2 = _GROUPS_IOTA_RE.search(line)
            if mg2:
                g = int(mg2.group(2))
        # effective wire bytes per chip (ring algorithms)
        if kind == "all-gather":
            wire = rbytes * (g - 1) / max(g, 1)
            operand = rbytes                      # gathered result
        elif kind == "all-reduce":
            wire = 2 * rbytes * (g - 1) / max(g, 1)
            operand = rbytes
        elif kind == "reduce-scatter":
            operand = rbytes * g                  # input is g× the output
            wire = rbytes * (g - 1)               # (g-1)/g of the input
        elif kind == "all-to-all":
            operand = rbytes
            wire = rbytes * (g - 1) / max(g, 1)
        else:                                     # collective-permute
            operand = rbytes
            wire = rbytes
        out.append({"kind": kind, "result_bytes": rbytes, "group": g,
                    "operand_bytes": operand, "wire_bytes": wire})
    return out


def summarize_collectives(hlo_text: str) -> Dict:
    """Aggregate :func:`parse_collectives` into the bench-gated summary.

    Returns ``{"collective_count", "operand_bytes", "wire_bytes",
    "counts_by_kind", "bytes_by_kind"}`` — per-chip totals over every
    collective in the (post-SPMD) HLO text.  ``operand_bytes`` is the sum of
    each collective's operand size, the quantity the distributed-op traffic
    closed forms (``repro.analysis.collectives.modeled_dist_traffic``) model
    and ``benchmarks/run.py dist`` gates as ``bytes_measured``.
    """
    colls = parse_collectives(hlo_text)
    counts: Dict[str, int] = {}
    bby: Dict[str, float] = {}
    for c in colls:
        counts[c["kind"]] = counts.get(c["kind"], 0) + 1
        bby[c["kind"]] = bby.get(c["kind"], 0.0) + c["operand_bytes"]
    return {
        "collective_count": len(colls),
        "operand_bytes": float(sum(c["operand_bytes"] for c in colls)),
        "wire_bytes": float(sum(c["wire_bytes"] for c in colls)),
        "counts_by_kind": counts,
        "bytes_by_kind": bby,
    }


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float      # operand bytes per chip
    wire_bytes: float            # effective ring-traffic bytes per chip
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    n_collectives: int
    by_kind: Dict[str, float]

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, chips: int = 1) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(compiled.as_text())
    cbytes = sum(c["operand_bytes"] for c in colls)
    wire = sum(c["wire_bytes"] for c in colls)
    by_kind: Dict[str, float] = {}
    for c in colls:
        by_kind[c["kind"]] = by_kind.get(c["kind"], 0.0) + c["wire_bytes"]
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": hbm / HBM_BW,
        "collective": wire / ICI_BW,
    }
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=cbytes, wire_bytes=wire,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], bottleneck=bottleneck,
        n_collectives=len(colls), by_kind=by_kind,
    )


def model_flops(n_params: int, tokens: int, kind: str,
                n_active: Optional[int] = None) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N_active·D for inference decode/prefill."""
    n = n_active if n_active is not None else n_params
    if kind == "train":
        return 6.0 * n_params * tokens if n_active is None else 6.0 * n * tokens
    return 2.0 * n * tokens
