"""Fault-injection harness for the guardrails layer (dispatch rule 10).

``tests/test_faults.py`` drives every injector below through the public
operators and asserts each fault lands on one of the **documented
contracts** — never on silence or a crash deep inside a kernel:

* ``"value"`` / ``"type"`` — rejected eagerly at the call site
  (``ValueError`` / ``TypeError`` from the pre-trace validators).
* ``"nonfinite"`` — rejected by ``nonfinite="raise"``
  (:class:`repro.core.guards.NonFiniteError`).
* ``"checkified"`` — caught by a staged in-jit assertion
  (``checkify.JaxRuntimeError`` under :func:`repro.core.guards.checked` with
  checks enabled).
* ``"degraded"`` — dispatch fell back with a warn-once
  :class:`repro.core.guards.ProbeFallbackWarning` (lowering faults).
* ``"ok"`` — the call completed: the documented behaviour for
  ``nonfinite="propagate"`` (IEEE semantics) and ``"sanitize"``
  (identity-element / greedy fallback).

The injectors are deterministic (seeded) so failures replay exactly.
"""
from __future__ import annotations

import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.guards import (  # noqa: F401  (re-exported harness hooks)
    NonFiniteError, ProbeFallbackWarning, checked, checks,
    force_probe_failure,
)

__all__ = [
    "OUTCOMES", "inject_nonfinite", "corrupt_offsets", "adversarial_params",
    "classify", "NonFiniteError", "ProbeFallbackWarning", "checked", "checks",
    "force_probe_failure",
]

OUTCOMES = ("ok", "value", "type", "nonfinite", "checkified", "degraded")


def inject_nonfinite(x: jax.Array, kind: str = "nan", frac: float = 0.1,
                     seed: int = 0) -> jax.Array:
    """Poison a deterministic fraction of ``x`` with a non-finite payload.

    Args:
        x: Float array to corrupt.
        kind: ``"nan"``, ``"inf"``, ``"-inf"``, or ``"extreme"`` (alternating
            ``±max_float`` — finite, but overflows any accumulation).
        frac: Fraction of elements to poison (at least one).
        seed: PRNG seed for the poisoned positions.

    Returns:
        A copy of ``x`` with the payload written at the chosen positions.

    Example:
        >>> x = inject_nonfinite(jnp.ones(8), "nan", frac=0.25)
        >>> int(jnp.isnan(x).sum())
        2
    """
    payloads = {
        "nan": np.nan, "inf": np.inf, "-inf": -np.inf,
        "extreme": None,
    }
    if kind not in payloads:
        raise ValueError(f"unknown kind {kind!r}; expected one of "
                         f"{tuple(payloads)}")
    arr = np.array(jnp.asarray(x), copy=True)
    flat = arr.reshape(-1)
    k = max(1, int(frac * flat.size))
    idx = np.random.default_rng(seed).choice(flat.size, size=k, replace=False)
    if kind == "extreme":
        big = np.finfo(flat.dtype).max
        flat[idx] = np.where(np.arange(k) % 2 == 0, big, -big)
    else:
        flat[idx] = payloads[kind]
    return jnp.asarray(arr)


def corrupt_offsets(offsets: jax.Array, mode: str = "unsorted") -> jax.Array:
    """Break a CSR offsets array in one specific, documented way.

    Args:
        offsets: Valid ``(num_segments + 1,)`` int offsets.
        mode: ``"unsorted"`` (swap two interior offsets), ``"negative"``
            (first entry below zero), ``"overrun"`` (last entry past ``n``),
            ``"head"`` (first entry nonzero), or ``"float"`` (float dtype —
            a ``TypeError``-class static fault).

    Returns:
        The corrupted offsets.

    Example:
        >>> o = corrupt_offsets(jnp.asarray([0, 3, 5]), "overrun")
        >>> o.tolist()
        [0, 3, 6]
    """
    off = np.array(jnp.asarray(offsets), copy=True)
    if mode == "unsorted":
        if off.shape[0] < 3:
            raise ValueError("unsorted needs at least two segments")
        mid = off.shape[0] // 2
        off[mid], off[mid - 1] = off[mid - 1], off[mid] + 1
    elif mode == "negative":
        off[0] = -1
    elif mode == "overrun":
        off[-1] = off[-1] + 1
    elif mode == "head":
        off[0] = 1
    elif mode == "float":
        return jnp.asarray(off, jnp.float32)
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return jnp.asarray(off)


def adversarial_params(which: str) -> dict:
    """Named adversarial sampler parameter sets for the fault suite.

    Example:
        >>> adversarial_params("p_over")["p"]
        1.5
    """
    table = {
        "p_over": {"p": 1.5},
        "p_under": {"p": -0.1},
        "p_nan": {"p": float("nan")},
        "temp_negative": {"temperature": -1.0},
        "temp_nan": {"temperature": float("nan")},
        "temp_inf": {"temperature": float("inf")},
        "temp_zero": {"temperature": 0.0},   # legal: greedy limit
    }
    if which not in table:
        raise ValueError(f"unknown param set {which!r}; expected one of "
                         f"{tuple(table)}")
    return dict(table[which])


def classify(fn, *args, **kwargs) -> Tuple[str, Optional[object]]:
    """Run ``fn(*args, **kwargs)`` and classify its outcome.

    Returns ``(outcome, detail)`` where ``outcome`` is one of ``OUTCOMES``
    and ``detail`` is the result (``"ok"``), the exception, or the warning.
    A :class:`ProbeFallbackWarning` emitted during an otherwise-successful
    call classifies as ``"degraded"``; any other exception type propagates —
    an *undocumented* failure is exactly what the fault suite must flag.

    Example:
        >>> from repro.core.scan import scan
        >>> classify(scan, jnp.ones(4), axis=7)[0]
        'value'
    """
    from jax.experimental import checkify

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        try:
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
        except NonFiniteError as e:
            return "nonfinite", e
        except checkify.JaxRuntimeError as e:
            return "checkified", e
        except TypeError as e:
            return "type", e
        except ValueError as e:
            return "value", e
    for w in caught:
        if issubclass(w.category, ProbeFallbackWarning):
            return "degraded", w
    return "ok", out
