import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Per-op collective attribution for one dry-run cell: top collective ops grouped by
(kind, shape), with counts and wire bytes — the profile used by §Perf hillclimbs.

  PYTHONPATH=src python -m repro.analysis.collectives --arch gemma2-2b \
      --shape train_4k [--mesh single]
"""
import argparse
import collections

from repro.analysis.roofline import _OP_RE, parse_collectives
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_debug_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--debug-mesh", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()
    mesh_fn = make_debug_mesh if args.debug_mesh else make_production_mesh
    mesh = mesh_fn(multi_pod=args.mesh == "multi")
    lowered, chips, _ = lower_cell(args.arch, args.shape, mesh)
    compiled = lowered.compile()
    txt = compiled.as_text()

    groups = collections.defaultdict(lambda: [0, 0.0])
    for line in txt.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_part, dtype, dims, kind = m.groups()
        shape = f"({tuple_part.strip()[:60]})" if tuple_part else f"{dtype}[{dims}]"
        recs = parse_collectives(line)
        wire = recs[0]["wire_bytes"] if recs else 0.0
        g = groups[(kind, shape)]
        g[0] += 1
        g[1] += wire
    total = sum(v[1] for v in groups.values())
    print(f"{args.arch} × {args.shape} × {args.mesh}: "
          f"{sum(v[0] for v in groups.values())} collectives, "
          f"{total / 1e9:.1f} GB wire/chip")
    rows = sorted(groups.items(), key=lambda kv: -kv[1][1])[:args.top]
    for (kind, shape), (n, wire) in rows:
        print(f"  {wire / 1e9:9.2f} GB  n={n:4d}  {kind:<20} {shape}")


if __name__ == "__main__":
    main()
