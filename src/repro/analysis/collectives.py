import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Collective-traffic accounting: measured (HLO-parsed) vs modeled (closed form).

Two consumers:

* **CLI** — per-op collective attribution for one dry-run cell: top collective
  ops grouped by (kind, shape), with counts and wire bytes — the profile used
  by §Perf hillclimbs.

      PYTHONPATH=src python -m repro.analysis.collectives --arch gemma2-2b \
          --shape train_4k [--mesh single]

* **Library** — the measured-vs-modeled traffic contract of the distributed
  operator family (``repro.core.dist_ops``): :func:`measure_collectives`
  compiles a callable and summarizes its HLO collectives;
  :func:`modeled_dist_traffic` produces the per-op closed forms derived in
  ``docs/distributed.md``.  ``benchmarks/run.py dist`` gates one against the
  other and commits both as ``bytes_measured`` / ``bytes_modeled`` columns in
  ``BENCH_dist.json``.
"""
import argparse
import collections
import math
from typing import Dict

_SORT_BITS = {"float32": 32, "bfloat16": 16, "float16": 16, "int32": 32,
              "int16": 16, "uint32": 32, "uint16": 16, "int8": 8, "uint8": 8}


def measure_collectives(fn, *args) -> Dict:
    """Compile ``fn(*args)`` and summarize its HLO collectives.

    Thin wrapper: ``jit`` -> ``lower`` -> ``compile`` -> parse the post-SPMD
    module text with :func:`repro.analysis.roofline.summarize_collectives`.
    Shapes only — nothing is executed, so this is safe on hosts without the
    target device count as long as the mesh itself can be built.
    """
    import jax
    from repro.analysis.roofline import summarize_collectives
    compiled = jax.jit(fn).lower(*args).compile()
    return summarize_collectives(compiled.as_text())


def _radix_schedule(bits: int, bits_per_pass: int):
    """Per-pass radix sizes ``2^k`` (a ragged final digit uses fewer bits)."""
    return [1 << min(bits_per_pass, bits - s)
            for s in range(0, bits, bits_per_pass)]


def modeled_dist_traffic(op: str, *, d: int, n: int, batch: int = 1,
                         dtype: str = "float32", bits_per_pass: int = 4,
                         itemsize: int = 4) -> Dict:
    """Closed-form per-chip collective traffic of a ``dist_*`` operator.

    The 2N + B-style forms of ``docs/distributed.md`` §Traffic, written
    against the same operand-bytes convention as
    :func:`~repro.analysis.roofline.parse_collectives` so the result compares
    *exactly* against :func:`measure_collectives` on the lowered op:

    * ``dist_sort``: per pass, one histogram ``all_gather`` (``4·D·batch·R``
      bytes — the B-term) and one dense bucket-exchange ``all_to_all``
      (``4·batch·D·C·n_local`` bytes, ``C = 2`` uint32 channels).
    * ``dist_top_p_sample``: the sort with ``C = 3`` channels over the 16
      bf16 key bits, plus two softmax all-reduces, two
      ``mcscan_local`` block-sum gathers, the shard-threshold gather, and
      two sampling all-reduces — every extra term is B-sized.
    * ``dist_linear_scan`` / ``dist_segment_scan``: a single ``all_gather``
      of the ``(A, B)`` affine carry pairs — ``2·itemsize·D·batch`` bytes
      total; the 2N term stays local to each shard.

    Args:
        op: ``"dist_sort"``, ``"dist_top_p_sample"``, ``"dist_linear_scan"``
            or ``"dist_segment_scan"``.
        d: Shard count ``D`` (mesh axis size).
        n: Global length of the sharded axis (pre-padding).
        batch: Product of the leading (batch) dims.
        dtype: Key dtype name for the sort pass count.
        bits_per_pass: Bits retired per radix pass.
        itemsize: Accumulation-dtype bytes for the carry pair (linrec /
            segmented).

    Returns:
        ``{"collective_count", "operand_bytes", "counts_by_kind"}`` —
        directly comparable with :func:`measure_collectives`' summary.
    """
    n_local = math.ceil(n / d)
    if op == "dist_sort":
        radixes = _radix_schedule(_SORT_BITS[dtype], bits_per_pass)
        ag = sum(4 * d * batch * r for r in radixes)
        a2a = len(radixes) * 4 * batch * d * 2 * n_local
        return {
            "collective_count": 2 * len(radixes),
            "operand_bytes": float(ag + a2a),
            "counts_by_kind": {"all-gather": len(radixes),
                               "all-to-all": len(radixes)},
        }
    if op == "dist_top_p_sample":
        radixes = _radix_schedule(16, bits_per_pass)       # bf16 keys
        ag_hist = sum(4 * d * batch * r for r in radixes)
        a2a = len(radixes) * 4 * batch * d * 3 * n_local   # key+token+prob
        ag_scan = 2 * 4 * d * batch                        # two mcscan gathers
        ag_tail = 4 * d * batch                            # shard thresholds
        ar = 4 * 4 * batch                                 # pmax+denom+rank+tok
        return {
            "collective_count": 2 * len(radixes) + 3 + 4,
            "operand_bytes": float(ag_hist + a2a + ag_scan + ag_tail + ar),
            "counts_by_kind": {"all-gather": len(radixes) + 3,
                               "all-to-all": len(radixes),
                               "all-reduce": 4},
        }
    if op in ("dist_linear_scan", "dist_segment_scan"):
        return {
            "collective_count": 1,
            "operand_bytes": float(2 * itemsize * d * batch),
            "counts_by_kind": {"all-gather": 1},
        }
    raise ValueError(f"modeled_dist_traffic: unknown op {op!r}")


def main():
    """CLI entry point (see module docstring)."""
    from repro.analysis.roofline import _OP_RE, parse_collectives
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_debug_mesh, make_production_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--debug-mesh", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()
    mesh_fn = make_debug_mesh if args.debug_mesh else make_production_mesh
    mesh = mesh_fn(multi_pod=args.mesh == "multi")
    lowered, chips, _ = lower_cell(args.arch, args.shape, mesh)
    compiled = lowered.compile()
    txt = compiled.as_text()

    groups = collections.defaultdict(lambda: [0, 0.0])
    for line in txt.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_part, dtype, dims, kind = m.groups()
        shape = f"({tuple_part.strip()[:60]})" if tuple_part else f"{dtype}[{dims}]"
        recs = parse_collectives(line)
        wire = recs[0]["wire_bytes"] if recs else 0.0
        g = groups[(kind, shape)]
        g[0] += 1
        g[1] += wire
    total = sum(v[1] for v in groups.values())
    print(f"{args.arch} × {args.shape} × {args.mesh}: "
          f"{sum(v[0] for v in groups.values())} collectives, "
          f"{total / 1e9:.1f} GB wire/chip")
    rows = sorted(groups.items(), key=lambda kv: -kv[1][1])[:args.top]
    for (kind, shape), (n, wire) in rows:
        print(f"  {wire / 1e9:9.2f} GB  n={n:4d}  {kind:<20} {shape}")


if __name__ == "__main__":
    main()
