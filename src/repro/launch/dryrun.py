import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture × input shape) against the
production meshes; record memory_analysis / cost_analysis / collective schedule.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/

train_4k lowers the *full train step* (loss + grad + AdamW update); prefill_32k the
prefill; decode_32k / long_500k the single-token ``serve_step`` against a full KV
cache (long_500k shards the cache sequence axis — SP — since batch == 1).
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rl
from repro.configs.base import SHAPES
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch.sharding_plan import batch_specs, cache_specs
from repro.models.model import (ARCHS, build_model, cell_supported, get_config,
                                input_specs)
from repro.training import optimizer as opt_lib
from repro.utils.sharding import param_shardings, use_mesh


def abstract_state(model, opt: bool = True, param_dtype: str = "float32"):
    """ShapeDtypeStruct pytrees for params (+ opt state) — no allocation."""
    dt = jnp.dtype(param_dtype)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), dtype=dt))
    if not opt:
        return params
    opt_state = jax.eval_shape(lambda p: opt_lib.adamw_init(p), params)
    return {"params": params, "opt": opt_state}


def _fit_sharding(mesh, sds, spec):
    """NamedSharding with non-divisible / missing axes dropped."""
    parts = []
    for dim, a in zip(sds.shape, tuple(spec) + (None,) * (len(sds.shape)
                                                          - len(spec))):
        if a is not None and a in mesh.axis_names and dim % mesh.shape[a] == 0:
            parts.append(a)
        else:
            parts.append(None)
    return NamedSharding(mesh, P(*parts))


def lower_cell(arch: str, shape_name: str, mesh, *, scan_method="matmul",
               scan_layers=False, overrides=None):
    """Returns (lowered, chips, n_params). Raises on sharding/compile errors.

    Layers are UNROLLED by default: XLA's cost_analysis counts while-loop bodies
    once, so scanned-layer modules under-report flops/bytes/collectives by ~n_layers
    — unrolling makes the roofline terms exact.  (Production training still scans;
    the lowered computation is identical per step.)
    """
    cfg = get_config(arch)
    over = dict(overrides or {})
    zero = over.pop("zero", False)                 # ZeRO-1: shard opt moments
    param_dtype = over.pop("param_dtype", "float32")
    cap = over.pop("moe_capacity", None)
    cfg = dataclasses.replace(cfg, scan_method=scan_method,
                              scan_layers=scan_layers, **over)
    if cap is not None and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cap))
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise SkipCell(why)
    model = build_model(cfg)
    opt_cfg = opt_lib.AdamWConfig()
    chips = mesh.devices.size

    if shape.kind == "train":
        state = abstract_state(model, param_dtype=param_dtype)
        pspecs = param_shardings(mesh, state["params"])
        mspecs = param_shardings(mesh, state["opt"]["mu"])
        if zero:
            # ZeRO-1: additionally shard each moment over "data" along the first
            # free (and divisible) dimension.
            def zero_shard(sds, ns):
                parts = list(tuple(ns.spec) + (None,) * (len(sds.shape)
                                                         - len(ns.spec)))
                for i, (dim, a) in enumerate(zip(sds.shape, parts)):
                    if a is None and dim % mesh.shape["data"] == 0 \
                            and dim >= mesh.shape["data"]:
                        parts[i] = "data"
                        break
                return NamedSharding(mesh, P(*parts))
            mspecs = jax.tree.map(zero_shard, state["opt"]["mu"], mspecs)
        sspecs = {"params": pspecs,
                  "opt": {"mu": mspecs, "nu": mspecs,
                          "step": NamedSharding(mesh, P())}}
        batch = input_specs(cfg, shape)
        bspecs = batch_specs(mesh, batch)

        def train_step(st, b):
            with use_mesh(mesh):
                (loss, _), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(st["params"], b)
                new_p, new_o, _ = opt_lib.adamw_update(
                    opt_cfg, grads, st["opt"], st["params"])
                return {"params": new_p, "opt": new_o}, loss

        fn = jax.jit(train_step, in_shardings=(sspecs, bspecs),
                     out_shardings=(sspecs, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
        lowered = fn.lower(state, batch)
        n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
        return lowered, chips, n_params

    params = abstract_state(model, opt=False)
    pspecs = param_shardings(mesh, params)
    n_params = sum(x.size for x in jax.tree.leaves(params))

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        bspecs = batch_specs(mesh, batch)

        def prefill(p, b):
            with use_mesh(mesh):
                logits, caches = model.prefill(p, b, cache_len=shape.seq_len)
                return logits, caches
        # let XLA choose cache output shardings; inputs are what matter here
        fn = jax.jit(prefill, in_shardings=(pspecs, bspecs))
        return fn.lower(params, batch), chips, n_params

    # decode: one new token against a filled cache of seq_len
    b = shape.global_batch
    caches = jax.eval_shape(
        lambda: model.empty_caches(b, shape.seq_len))
    cspecs = cache_specs(mesh, caches, seq_sharded=b == 1)
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tspec = batch_specs(mesh, {"tokens": tokens})["tokens"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(p, t, c, pos):
        with use_mesh(mesh):
            logits, c = model.decode_step(p, t, c, pos)
            return jnp.argmax(logits, -1).astype(jnp.int32), c

    tok_out = NamedSharding(mesh, P(*tspec.spec[:1]))    # rank-1 sampled tokens
    fn = jax.jit(serve_step,
                 in_shardings=(pspecs, tspec, cspecs, NamedSharding(mesh, P())),
                 out_shardings=(tok_out, cspecs), donate_argnums=(2,))
    return fn.lower(params, tokens, caches, pos), chips, n_params


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, debug=False,
             scan_method="matmul", overrides=None, mesh_shape=None, tag=""):
    if mesh_shape is not None:
        d, m = mesh_shape
        from repro.utils.compat import make_mesh
        mesh = make_mesh((d, m), ("data", "model"))
    else:
        mesh_fn = make_debug_mesh if debug else make_production_mesh
        mesh = mesh_fn(multi_pod=mesh_kind == "multi")
    t0 = time.time()
    lowered, chips, n_params = lower_cell(arch, shape_name, mesh,
                                          scan_method=scan_method,
                                          overrides=overrides)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    mem = compiled.memory_analysis()
    roof = rl.analyze(compiled, chips=chips)
    shape = SHAPES[shape_name]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mflops = rl.model_flops(n_params, tokens, shape.kind)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "tag": tag, "status": "ok",
        "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
        "n_params": n_params,
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "alias": mem.alias_size_in_bytes,
            "code": mem.generated_code_size_in_bytes,
        },
        "roofline": roof.to_dict(),
        "model_flops_global": mflops,
        "model_flops_per_chip": mflops / chips,
        "useful_fraction": (mflops / chips) / roof.flops if roof.flops else 0.0,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default=None)
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--debug-mesh", action="store_true",
                    help="8-device debug mesh (CI)")
    ap.add_argument("--scan-method", default="matmul",
                    choices=["matmul", "vector"])
    ap.add_argument("--zero", action="store_true", help="ZeRO-1 opt sharding")
    ap.add_argument("--param-dtype", default="float32")
    ap.add_argument("--mesh-shape", default=None,
                    help="override logical mesh as DxM, e.g. 32x8")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--tag", default="", help="perf-iteration tag for the record")
    args = ap.parse_args()

    overrides = {}
    if args.zero:
        overrides["zero"] = True
    if args.param_dtype != "float32":
        overrides["param_dtype"] = args.param_dtype
    if args.capacity_factor is not None:
        overrides["moe_capacity"] = args.capacity_factor
    mesh_shape = None
    if args.mesh_shape:
        d, m = args.mesh_shape.split("x")
        mesh_shape = (int(d), int(m))

    cells = []
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            for mk in meshes:
                cells.append((a, s, mk))

    records = []
    failed = 0
    for arch, shape, mk in cells:
        tag = f"{arch} × {shape} × {mk}"
        try:
            rec = run_cell(arch, shape, mk, debug=args.debug_mesh,
                           scan_method=args.scan_method, overrides=overrides,
                           mesh_shape=mesh_shape, tag=args.tag)
            r = rec["roofline"]
            print(f"[dryrun] OK  {tag}: compute {r['compute_s']*1e3:.2f}ms "
                  f"memory {r['memory_s']*1e3:.2f}ms collective "
                  f"{r['collective_s']*1e3:.2f}ms -> {r['bottleneck']}"
                  f" (compile {rec['compile_s']}s)", flush=True)
        except SkipCell as e:
            rec = {"arch": arch, "shape": shape, "mesh": mk,
                   "status": "skip", "reason": str(e)}
            print(f"[dryrun] SKIP {tag}: {e}", flush=True)
        except Exception as e:  # noqa
            rec = {"arch": arch, "shape": shape, "mesh": mk,
                   "status": "fail", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"[dryrun] FAIL {tag}: {type(e).__name__}: {e}", flush=True)
            failed += 1
        records.append(rec)
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            suffix = f"_{args.tag}" if args.tag else ""
            fname = os.path.join(
                args.out,
                f"dryrun_{arch}_{shape}_{mk}{suffix}.json".replace("/", "_"))
            with open(fname, "w") as f:
                json.dump(rec, f, indent=1)
    print(f"[dryrun] {len(records) - failed}/{len(records)} cells passed")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
