"""Production mesh factory (functions only — importing never touches jax devices)."""
from __future__ import annotations

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Small mesh for CI smoke-runs of the dry-run machinery (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
