"""Launch helpers — dry-run sharding/topology planning."""
