"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real cluster the same entry point runs under ``jax.distributed.initialize``
with the production mesh; here the smoke configs exercise the full path on CPU.
Fault tolerance: checkpoint every ``--ckpt-every`` steps; re-running the same
command resumes from the latest checkpoint (restart-safe data pipeline).
"""
from __future__ import annotations

import argparse
import dataclasses


from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models.model import ARCHS, get_config
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["none", "debug", "prod", "prod-multi"],
                    default="none")
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. ~100M example model)")
    ap.add_argument("--layers", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    over = {}
    if args.d_model:
        over["d_model"] = args.d_model
    if args.layers:
        over["n_layers"] = args.layers
    if over:
        cfg = dataclasses.replace(cfg, **over)

    mesh = None
    if args.mesh == "debug":
        mesh = make_debug_mesh()
    elif args.mesh.startswith("prod"):
        mesh = make_production_mesh(multi_pod=args.mesh == "prod-multi")

    trainer = Trainer(cfg, AdamWConfig(lr=args.lr, warmup_steps=20,
                                       total_steps=args.steps),
                      mesh=mesh, ckpt_dir=args.ckpt_dir,
                      grad_accum=args.grad_accum)
    src = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    out = trainer.fit(src, args.steps, log_every=10,
                      ckpt_every=args.ckpt_every if args.ckpt_dir else 0)
    print(f"[train] final loss {out['losses'][-1]:.4f} "
          f"(start {out['losses'][0]:.4f})")


if __name__ == "__main__":
    main()
