"""Cell-level sharding plans: batch, KV-cache and optimizer-state PartitionSpecs."""
from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.sharding import dp_axes


def batch_specs(mesh: Mesh, batch_tree):
    dp = dp_axes(mesh)
    dp_size = 1
    for a in (dp or ()):
        dp_size *= mesh.shape[a]

    def spec(x):
        if not x.shape or x.shape[0] % dp_size:
            return NamedSharding(mesh, P())      # batch==1 (long-context): replicate
        return NamedSharding(mesh, P(*((dp,) + (None,) * (len(x.shape) - 1))))
    return jax.tree.map(spec, batch_tree)


# Decode-cache rules, matched on the flattened path ('/'-joined dict keys).
# Each rule lists CANDIDATE specs in preference order (the tensor's own, unstacked
# layout; leading layer-stack dims are padded with None).  The first candidate whose
# sharded axes all divide evenly is chosen — e.g. GQA caches put kv-heads on
# "model" when n_kv_heads ≥ TP degree, else fall back to sharding the cache
# *sequence* axis over "model".
# seq mode (batch==1 long-context) shards the time axis over "data" (SP).
_CACHE_RULES = [
    (re.compile(r"(^|/)(k|v)$"),
     {"batch": [("dp", None, "model", None), ("dp", "model", None, None)],
      "seq": [(None, "data", "model", None), (None, ("data", "model"), None, None)]}),
    (re.compile(r"latent$"), {"batch": [("dp", None, None)],
                              "seq": [(None, "data", None)]}),
    (re.compile(r"k_rope$"), {"batch": [("dp", None, None)],
                              "seq": [(None, "data", None)]}),
    (re.compile(r"ssm$"), {"batch": [("dp", "model", None, None)],
                           "seq": [(None, "model", None, None)]}),
    (re.compile(r"conv$"), {"batch": [("dp", None, "model")],
                            "seq": [(None, None, "model")]}),
    (re.compile(r"(^|/)c$"),
     {"batch": [("dp", "model", None, None), ("dp", None, "model", None)],
      "seq": [(None, "model", None, None), (None, None, "model", None)]}),
    (re.compile(r"(^|/)n$"),
     {"batch": [("dp", "model", None), ("dp", None, "model")],
      "seq": [(None, "model", None), (None, None, "model")]}),
    (re.compile(r"(^|/)m$"), {"batch": [("dp", "model"), ("dp", None)],
                              "seq": [(None, "model"), (None, None)]}),
    (re.compile(r"rec"),
     {"batch": [("dp", "model", None), ("dp", None, "model")],
      "seq": [(None, "model", None), (None, None, "model")]}),
]


def _axis_size(mesh: Mesh, a) -> int:
    if a is None:
        return 1
    if isinstance(a, tuple):
        n = 1
        for x in a:
            n *= mesh.shape.get(x, 1)
        return n
    return mesh.shape.get(a, 1)


def cache_specs(mesh: Mesh, cache_tree, *, seq_sharded: bool):
    dp = dp_axes(mesh)
    mode = "seq" if seq_sharded else "batch"

    def path_str(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
        return "/".join(parts)

    def resolve(spec, shape):
        """Pad to rank, drop missing axes, null non-divisible entries."""
        ndim = len(shape)
        spec = tuple(dp if a == "dp" else a for a in spec)
        if len(spec) < ndim:
            spec = (None,) * (ndim - len(spec)) + spec
        elif len(spec) > ndim:
            spec = spec[-ndim:]
        out = []
        clean = True
        for dim, a in zip(shape, spec):
            if a is not None and not isinstance(a, tuple) \
                    and a not in mesh.axis_names:
                a = None
            if isinstance(a, tuple):
                a = tuple(x for x in a if x in mesh.axis_names) or None
            if a is not None and dim % _axis_size(mesh, a):
                a = None
                clean = False
            out.append(a)
        return tuple(out), clean

    def leaf_spec(kp, x):
        path = path_str(kp)
        for rx, table in _CACHE_RULES:
            if rx.search(path):
                chosen = None
                for cand in table[mode]:
                    spec, clean = resolve(cand, x.shape)
                    if chosen is None:
                        chosen = spec
                    if clean:
                        chosen = spec
                        break
                return NamedSharding(mesh, P(*chosen))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_tree)
