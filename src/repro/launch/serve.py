"""Serving launcher: batched generation with the paper's scan-based top-p sampler.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16 --sampler topp_scan
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.models.model import ARCHS, build_model, get_config, synth_batch
from repro.configs.base import ShapeConfig
from repro.serving.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS), default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--top-p", type=float, default=0.9)
    ap.add_argument("--sampler", choices=["topp_scan", "topp_xla", "greedy"],
                    default="topp_scan")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")
    batch = synth_batch(cfg, shape, jax.random.PRNGKey(1))

    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.new_tokens +
                      (cfg.n_img_tokens if cfg.family == "vlm" else 0),
                      top_p=args.top_p, sampler=args.sampler)
    t0 = time.perf_counter()
    toks = eng.generate(batch, args.new_tokens, jax.random.PRNGKey(2))
    dt = time.perf_counter() - t0
    toks = np.asarray(toks)
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s) sampler={args.sampler}")
    print(toks[:, :12])


if __name__ == "__main__":
    main()
