"""Compare fresh ``BENCH_*.json`` files against the committed baselines.

The bench-smoke CI job runs ``benchmarks/run.py --smoke --json-out bench-out``
and then this script, so the baselines under ``benchmarks/baseline/`` actually
gate regressions instead of only being uploaded as an artifact:

* **structure** — every baseline file must have a fresh counterpart, and every
  baseline row name must appear in the fresh file (a vanished section or row
  fails the job; *new* rows/files are reported but allowed — the suite grows).
* **exact derived metrics** — machine-independent model quantities embedded
  in the ``derived`` column (``passes``, ``expected``, ``bits``,
  ``bytes_moved``, ``n``, ``scans_per_batch``, and the serve section's
  schedule-derived ``tokens``/``reqs``/``steps``/``peak_pages``/
  ``p50_steps``/``p99_steps``/``while_loops``, and the dist section's
  ``bytes_modeled``/``bytes_measured``/``collective_count``) must match
  exactly: they encode algorithmic facts (launch counts, traffic models,
  deterministic schedules), not timings.  A gated key that is
  present in the baseline row but *missing* from the fresh row is a hard
  failure too — otherwise a benchmark edit that drops a derived column (say
  ``max_ulp``) silently un-gates it.
* **bounded derived metrics** — accuracy floats (``max_ulp``) are gated with
  slack instead of exactly: the fresh value must stay within
  ``ULP_FACTOR``x the baseline plus ``ULP_SLACK`` ulps (contraction order,
  and hence the ulp count, legitimately varies across BLAS builds), and when
  the row also carries its documented ``ulp_bound`` the fresh value must not
  exceed it — that is the precision contract itself, machine-independent.
* **timings** — ``us_per_call`` is compared *after normalizing out machine
  speed*: the median of ``fresh/baseline`` ratios across **all** files is
  taken as the machine-speed scale, and each row's normalized ratio must stay
  below ``1 + rtol``.  An operator or a whole section regressing relative to
  the rest of the suite fails even on a slower/faster runner; a uniformly
  slower machine does not.  (The scale is global, not per file, so a change
  that slows every row of one section — or one row of a two-row section —
  cannot hide inside its own normalization.)
* **auto vs oracle** — every fresh ``method="auto"`` row (a ``/auto`` name
  component) must be within ``--auto-factor`` (default 4x) of the best
  *measured* concrete method on the same (op, n, dtype) row set — the
  per-row oracle.  This gates the committed tuning table itself: a stale or
  wrong table makes ``auto`` pick a slow method and the factor trips.  The
  factor is deliberately loose (4x) because smoke rows are µs-scale and a
  single dispatch hiccup can double a measurement; the point is to catch
  "auto resolved to a method 10-100x off the crossover", not to re-litigate
  timing noise.  Checked on fresh files only — no baseline needed.

Usage::

    python tools/compare_bench.py bench-out benchmarks/baseline [--rtol RTOL]
        [--auto-factor FACTOR]

Exit status is non-zero on any failure (this is what fails CI).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import statistics
import sys

EXACT_KEYS = ("passes", "expected", "bits", "bytes_moved", "n",
              "scans_per_batch",
              # serve section: schedule-derived quantities (token counts,
              # virtual-step latencies, page-pool peaks, while-loop launch
              # counts) are pure functions of the seeded arrival trace —
              # machine-independent, so gated exactly
              "tokens", "reqs", "steps", "peak_pages", "p50_steps",
              "p99_steps", "while_loops",
              # dist section: the measured-vs-modeled traffic contract —
              # collective counts and operand bytes parsed from the lowered
              # HLO, plus the closed-form model; both are shape-derived, so
              # gated exactly
              "bytes_modeled", "bytes_measured", "collective_count")
# accuracy floats: gated within a factor + slack of baseline, and against the
# row's own documented ulp_bound when present (see module docstring)
BOUNDED_KEYS = ("max_ulp",)
ULP_FACTOR = 4.0
ULP_SLACK = 4.0


def _load(path: str) -> dict:
    """Load one BENCH file as ``{row name: row dict}``."""
    with open(path) as fh:
        return {r["name"]: r for r in json.load(fh)}


def _derived_map(derived: str) -> dict:
    """Parse the ``;``-separated ``key=value`` derived column."""
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def compare_file(name: str, fresh: dict, base: dict) -> "tuple[list, dict]":
    """Compare one section's structure and exact derived metrics.

    Returns ``(failures, timing ratios)`` — the timing check itself runs in
    :func:`main` against the suite-wide machine-speed scale.
    """
    fails = []
    missing = sorted(set(base) - set(fresh))
    for m in missing:
        fails.append(f"{name}: row {m!r} present in baseline but missing")
    new = sorted(set(fresh) - set(base))
    if new:
        print(f"  note: {name} has {len(new)} new row(s) (allowed)")
    shared = sorted(set(base) & set(fresh))
    # derived metrics: a gated key present in the baseline row but absent
    # from the fresh row is a hard failure (dropping the column must not
    # silently un-gate it), then exact keys compare exactly and bounded keys
    # within factor + slack (plus the row's own documented ulp_bound).
    for r in shared:
        bd = _derived_map(base[r].get("derived", ""))
        fd = _derived_map(fresh[r].get("derived", ""))
        for k in EXACT_KEYS + BOUNDED_KEYS:
            if k in bd and k not in fd:
                fails.append(
                    f"{name}: {r}: derived key {k!r} present in baseline but "
                    "missing from the fresh row (un-gating is not allowed)")
        for k in EXACT_KEYS:
            if k in bd and k in fd and bd[k] != fd[k]:
                fails.append(
                    f"{name}: {r}: derived {k}={fd[k]} != baseline {bd[k]}")
        for k in BOUNDED_KEYS:
            if k in bd and k in fd:
                bv, fv = float(bd[k]), float(fd[k])
                allowed = ULP_FACTOR * bv + ULP_SLACK
                if fv > allowed:
                    fails.append(
                        f"{name}: {r}: derived {k}={fv:.2f} exceeds "
                        f"baseline {bv:.2f} beyond the allowance "
                        f"({ULP_FACTOR}x + {ULP_SLACK} = {allowed:.2f})")
    ratios = {}
    for r in shared:
        bt, ft = base[r]["us_per_call"], fresh[r]["us_per_call"]
        if bt > 0 and ft > 0:
            ratios[f"{name}: {r}"] = ft / bt
    return fails, ratios


def check_ulp_contract(name: str, fresh: dict) -> list:
    """Self-contained precision gate: ``max_ulp <= ulp_bound`` per fresh row.

    Runs on *every* fresh row carrying both keys — baseline or not — because
    the bound is the documented contract of ``repro.analysis.ulp``, not a
    machine-relative quantity.
    """
    fails = []
    for rname, r in sorted(fresh.items()):
        fd = _derived_map(r.get("derived", ""))
        if "max_ulp" in fd and "ulp_bound" in fd:
            if float(fd["max_ulp"]) > float(fd["ulp_bound"]):
                fails.append(
                    f"{name}: {rname}: max_ulp={fd['max_ulp']} exceeds the "
                    f"documented precision bound ulp_bound={fd['ulp_bound']}")
    return fails


def check_auto_vs_oracle(name: str, fresh: dict, factor: float) -> list:
    """Gate ``method="auto"`` rows against the best measured concrete method.

    A row belongs to the gate when one ``/``-separated component of its name
    is exactly ``auto``; its oracle group is every row whose name differs only
    in that component.  Fails when no concrete sibling was measured, or when
    ``auto`` is more than ``factor`` slower than the fastest sibling.
    """
    fails = []
    for rname, r in sorted(fresh.items()):
        parts = rname.split("/")
        if "auto" not in parts:
            continue
        i = parts.index("auto")
        siblings = {}
        for other, ro in fresh.items():
            op = other.split("/")
            if (len(op) == len(parts) and op[:i] == parts[:i]
                    and op[i + 1:] == parts[i + 1:] and op[i] != "auto"
                    and ro["us_per_call"] > 0):
                siblings[op[i]] = ro["us_per_call"]
        if not siblings:
            fails.append(f"{name}: {rname}: auto row has no measured "
                         "concrete-method siblings to compare against")
            continue
        best_m = min(siblings, key=siblings.get)
        best_t, auto_t = siblings[best_m], r["us_per_call"]
        if auto_t > factor * best_t:
            fails.append(
                f"{name}: {rname}: auto {auto_t:.1f}us is "
                f"{auto_t / best_t:.1f}x the best measured method "
                f"({best_m}, {best_t:.1f}us); allowed factor {factor}")
    return fails


def main() -> int:
    """CLI entry point; returns the process exit status."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh_dir", help="directory with freshly written BENCH_*.json")
    ap.add_argument("baseline_dir", help="directory with committed baselines")
    ap.add_argument("--rtol", type=float, default=6.0,
                    help="allowed normalized slowdown per row (default 6.0 = "
                         "7x; smoke rows are µs-scale and dispatch-noise "
                         "dominated, so the timing gate is a coarse backstop "
                         "— the exact derived metrics are the sharp one)")
    ap.add_argument("--auto-factor", type=float, default=4.0,
                    help="allowed slowdown of a method='auto' row vs the best "
                         "measured concrete method on the same row set "
                         "(default 4.0; see module docstring)")
    args = ap.parse_args()

    base_files = sorted(glob.glob(os.path.join(args.baseline_dir, "BENCH_*.json")))
    if not base_files:
        print(f"no baselines under {args.baseline_dir}", file=sys.stderr)
        return 2
    fails = []
    all_ratios = {}
    for bf in base_files:
        fname = os.path.basename(bf)
        ff = os.path.join(args.fresh_dir, fname)
        print(f"comparing {fname}")
        if not os.path.exists(ff):
            fails.append(f"{fname}: baseline exists but no fresh file was produced")
            continue
        fresh_rows = _load(ff)
        file_fails, ratios = compare_file(fname, fresh_rows, _load(bf))
        fails.extend(file_fails)
        fails.extend(check_auto_vs_oracle(fname, fresh_rows,
                                          args.auto_factor))
        fails.extend(check_ulp_contract(fname, fresh_rows))
        all_ratios.update(ratios)
    # timings, normalized by the suite-wide median ratio (machine speed) so a
    # section-wide slowdown cannot hide inside its own file's normalization
    if all_ratios:
        scale = statistics.median(all_ratios.values())
        print(f"machine-speed scale (suite-wide median fresh/baseline): "
              f"{scale:.2f}x over {len(all_ratios)} rows")
        for r, ratio in sorted(all_ratios.items()):
            norm = ratio / scale
            if norm > 1 + args.rtol:
                fails.append(
                    f"{r}: {norm:.2f}x slower than the suite vs baseline "
                    f"(raw {ratio:.2f}x, machine scale {scale:.2f}x, "
                    f"rtol {args.rtol})")
    fresh_only = sorted(
        set(os.path.basename(p)
            for p in glob.glob(os.path.join(args.fresh_dir, "BENCH_*.json"))) -
        set(os.path.basename(p) for p in base_files))
    for f in fresh_only:
        print(f"  note: {f} has no baseline yet (allowed; commit one to gate it)")
        rows = _load(os.path.join(args.fresh_dir, f))
        fails.extend(check_auto_vs_oracle(f, rows, args.auto_factor))
        fails.extend(check_ulp_contract(f, rows))
    if fails:
        print(f"\nFAIL: {len(fails)} benchmark drift(s):", file=sys.stderr)
        for f in fails:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nOK: fresh benchmarks match the committed baselines")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
