#!/usr/bin/env python
"""Link checker for the docs subsystem (CI docs job; no dependencies).

Validates, over ``docs/*.md`` and ``README.md``:

* markdown links ``[text](target)`` whose target is a relative path — the file
  must exist (http(s)/mailto/# anchors are skipped);
* backtick code-span anchors of the form ``path/to/file.py:123`` or
  ``path:12-34`` — the file must exist *and* be long enough, so the
  ``docs/paper_map.md`` file:line anchors go stale loudly instead of silently.

Exit code 0 when everything resolves, 1 with a report otherwise.
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_ANCHOR = re.compile(
    r"`([\w][\w./-]*\.(?:py|md|toml|yml|yaml|json)):(\d+)(?:-(\d+))?`")
CODE_PATH = re.compile(r"`([\w][\w./-]*/[\w.-]+\.(?:py|md|toml|yml|yaml|json))`")


def _check_file(md_path: str) -> list[str]:
    errors = []
    text = open(md_path, encoding="utf-8").read()
    base = os.path.dirname(md_path)
    rel = os.path.relpath(md_path, ROOT)

    for m in MD_LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = os.path.normpath(os.path.join(base, path))
        if not os.path.exists(resolved):
            errors.append(f"{rel}: broken link -> {target}")

    seen = set()
    for m in CODE_ANCHOR.finditer(text):
        path, lo, hi = m.group(1), int(m.group(2)), m.group(3)
        hi = int(hi) if hi else lo
        resolved = os.path.normpath(os.path.join(ROOT, path))
        key = (path, lo, hi)
        if key in seen:
            continue
        seen.add(key)
        if not os.path.exists(resolved):
            errors.append(f"{rel}: anchor to missing file -> {path}:{lo}")
            continue
        n_lines = sum(1 for _ in open(resolved, encoding="utf-8"))
        if hi > n_lines:
            errors.append(
                f"{rel}: stale anchor -> {path}:{lo}"
                f"{'-' + str(hi) if hi != lo else ''} (file has {n_lines} lines)")

    for m in CODE_PATH.finditer(text):
        path = m.group(1)
        if any(ch in path for ch in "*{<"):
            continue
        resolved = os.path.normpath(os.path.join(ROOT, path))
        if not os.path.exists(resolved):
            errors.append(f"{rel}: reference to missing file -> {path}")

    return errors


def main() -> int:
    targets = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    targets.append(os.path.join(ROOT, "README.md"))
    all_errors = []
    for path in targets:
        all_errors.extend(_check_file(path))
    if all_errors:
        print(f"{len(all_errors)} broken doc reference(s):")
        for e in all_errors:
            print(f"  {e}")
        return 1
    print(f"checked {len(targets)} file(s): all links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
