#!/usr/bin/env python
"""Regenerate or validate the committed ``method="auto"`` tuning table.

Two modes:

* **generate** (default): read every ``BENCH_*.json`` under ``--bench-dir``
  (default ``benchmarks/baseline/``), derive the piecewise length-bucket
  crossover table via :func:`repro.core.autotune.build_table`, stamp
  provenance (host, jax version, bench git rev), and write it to
  ``src/repro/configs/tuning/default.json`` (or ``--output``).  Pass
  ``--run-sweep`` to first run a fresh ``benchmarks/run.py --smoke`` sweep
  into a temp dir and tune from that instead of the committed baselines.

* ``--check``: the CI ``tuning-table`` job.  Validates the committed table's
  schema and coverage (an entry or explicit fallback for every tuned op),
  then regenerates from the committed baselines and fails on any drift
  (provenance excluded) — the shipped table can never silently diverge from
  the shipped measurements.

Exit status 0 on success, 1 on any validation/drift failure.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core.autotune import (  # noqa: E402
    SCHEMA_VERSION, build_table, default_table_path, load_table,
    validate_table,
)


def read_bench_rows(bench_dir: str) -> list:
    """All rows of every ``BENCH_*.json`` in ``bench_dir`` (sorted by file)."""
    rows = []
    names = sorted(f for f in os.listdir(bench_dir)
                   if f.startswith("BENCH_") and f.endswith(".json"))
    if not names:
        raise SystemExit(f"no BENCH_*.json files in {bench_dir}")
    for name in names:
        with open(os.path.join(bench_dir, name)) as f:
            rows.extend(json.load(f))
    return rows


def gather_provenance(bench_dir: str) -> dict:
    """Informational metadata for the generated table (ignored by --check)."""
    import jax
    try:
        rev = subprocess.run(
            ["git", "-C", REPO, "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10).stdout.strip() or None
    except Exception:
        rev = None
    return {
        "host": platform.node(),
        "platform": platform.platform(),
        "jax_version": jax.__version__,
        "bench_git_rev": rev,
        "bench_dir": os.path.relpath(bench_dir, REPO),
    }


def strip_provenance(table: dict) -> dict:
    return {k: v for k, v in table.items() if k != "provenance"}


def run_sweep(out_dir: str, smoke: bool) -> None:
    """Run benchmarks/run.py with --json-out into out_dir (fresh tuning data)."""
    cmd = [sys.executable, os.path.join(REPO, "benchmarks", "run.py"),
           "--json-out", out_dir] + (["--smoke"] if smoke else ["--full"])
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    print(f"$ {' '.join(cmd)}", flush=True)
    subprocess.run(cmd, check=True, env=env)


def check(bench_dir: str, table_path: str) -> int:
    """Validate schema/coverage and gate drift vs the committed baselines."""
    try:
        with open(table_path) as f:
            committed = json.load(f)
    except Exception as e:
        print(f"FAIL: cannot read committed table {table_path}: {e}")
        return 1
    problems = validate_table(committed)
    for p in problems:
        print(f"FAIL(schema): {p}")
    loaded = load_table()
    if loaded is None:
        problems.append("package data not loadable")
        print("FAIL(package): importlib.resources cannot load the table "
              "(check pyproject package-data and src/repro/__init__.py)")
    elif strip_provenance(loaded) != strip_provenance(committed):
        problems.append("package data != committed file")
        print(f"FAIL(package): table loaded from package data differs from "
              f"{table_path}")
    regen = build_table(read_bench_rows(bench_dir),
                        backend=committed.get("default_backend", "cpu"))
    if strip_provenance(regen) != strip_provenance(committed):
        problems.append("drift")
        print("FAIL(drift): regenerating from the committed baselines yields "
              "a different table; run `python tools/tune.py` and commit the "
              "result")
        print("--- regenerated ---")
        print(json.dumps(strip_provenance(regen), indent=2, sort_keys=True))
    if problems:
        return 1
    nops = sum(len(ops) for ops in committed.get("backends", {}).values())
    print(f"OK: schema v{SCHEMA_VERSION}, {nops} op entries, "
          f"{len(committed.get('fallbacks', {}))} explicit fallbacks, "
          "no drift vs baselines")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--bench-dir",
                    default=os.path.join(REPO, "benchmarks", "baseline"),
                    help="directory of BENCH_*.json inputs")
    ap.add_argument("--output", default=default_table_path(),
                    help="where to write the table (generate mode)")
    ap.add_argument("--backend", default="cpu",
                    help="backend label for the measurements")
    ap.add_argument("--run-sweep", action="store_true",
                    help="run a fresh benchmarks/run.py sweep first and tune "
                         "from its output instead of --bench-dir")
    ap.add_argument("--full", action="store_true",
                    help="with --run-sweep: full sizes instead of --smoke")
    ap.add_argument("--check", action="store_true",
                    help="validate the committed table + drift gate (CI)")
    args = ap.parse_args()

    if args.check:
        return check(args.bench_dir, args.output)

    bench_dir = args.bench_dir
    if args.run_sweep:
        import tempfile
        bench_dir = tempfile.mkdtemp(prefix="tune_sweep_")
        run_sweep(bench_dir, smoke=not args.full)
    table = build_table(read_bench_rows(bench_dir), backend=args.backend,
                        provenance=gather_provenance(bench_dir))
    os.makedirs(os.path.dirname(args.output), exist_ok=True)
    with open(args.output, "w") as f:
        json.dump(table, f, indent=2, sort_keys=True)
        f.write("\n")
    problems = validate_table(table)
    for p in problems:
        print(f"WARN: {p}")
    print(f"wrote {args.output}")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
