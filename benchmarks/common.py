"""Benchmark timing helpers (CPU host timings; bandwidth derived as bytes/time).

Rows are printed as CSV and collected in memory; ``dump_json`` writes one
``BENCH_<section>.json`` per section (section = first path component of the row
name), which CI uploads as the perf-trajectory artifact.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

_ROWS: List[Dict] = []


def timeit(fn, *args, repeats: int = 5, warmup: int = 2):
    """Median wall time (s) of jitted fn; blocks on results."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
    _ROWS.append({"name": name, "us_per_call": round(seconds * 1e6, 1),
                  "derived": derived})


def dump_json(out_dir: str) -> List[str]:
    """Write collected rows as BENCH_<section>.json files; returns the paths."""
    os.makedirs(out_dir, exist_ok=True)
    by_section: Dict[str, List[Dict]] = {}
    for r in _ROWS:
        by_section.setdefault(r["name"].split("/")[0], []).append(r)
    paths = []
    for section, rows in sorted(by_section.items()):
        path = os.path.join(out_dir, f"BENCH_{section}.json")
        with open(path, "w") as fh:
            json.dump(rows, fh, indent=1)
        paths.append(path)
    return paths
