"""Benchmark timing helpers (CPU host timings; bandwidth derived as bytes/time)."""
from __future__ import annotations

import time

import jax
import numpy as np


def timeit(fn, *args, repeats: int = 5, warmup: int = 2):
    """Median wall time (s) of jitted fn; blocks on results."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)
