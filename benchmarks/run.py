"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  All sections run on the host CPU (the
TARGET is TPU; these benchmarks validate relative behaviour — scan strategy
ratios, traffic counts, operator scaling — rather than absolute device numbers;
the TPU-side projection lives in EXPERIMENTS.md §Roofline).

  Fig 3  single-core scan: vector CumSum vs ScanU vs ScanUL1
  Fig 5  batched scan: ScanUL1/ScanU execution-time ratio grid
  Fig 8  MCScan bandwidth vs length (s = 32/64/128) + copy roofline  [8 devices]
  Fig 9  MCScan int8 vs fp16 GElems/s                                [8 devices]
  Fig 10 compress vs baseline masked-select
  Fig 11 radix sort vs jnp.sort (fp16)
  Fig 12 batched scan bandwidth vs batch size (len 65K)
  Fig 13 top-p sampling: baseline sort+cumsum vs radix+MCScan build

  scan_pipeline  blocked §4 pipeline: achieved bytes/s vs memcpy baseline
                 (the paper's headline 74.9%-of-memcpy metric) across methods
                 and dtypes -> BENCH_scan_pipeline.json
  sort           radix-2^k sweep: method × dtype × bits_per_pass with
                 pass-count and bytes-moved columns (plus a trace-only guard
                 that the fused sort runs ceil(bits/k) passes)
                 -> BENCH_sort.json
  segscan        segmented scan: segment-count × mean-segment-length × method
                 on ragged packed batches, vs the dense-pad baseline
                 -> BENCH_segscan.json
  linrec         linear-recurrence scan (y = a*y_prev + b): batch × length ×
                 method × dtype on gated-decay payloads — the recurrent-model
                 decode workload on the weighted-triangular matmul scan
                 -> BENCH_linrec.json
  precision      precision axis (highest/compensated/fast) on the matmul-
                 engine methods: time + max-ulp-vs-fp64 per op × method ×
                 precision, gated against the documented ulp bound
                 -> BENCH_precision.json
  serve          continuous batching under a seeded Poisson arrival trace:
                 paged-KV ContinuousEngine vs the dense sequential baseline,
                 tokens/s + p50/p99 per-token step latency + page-pool
                 utilization, plus a trace-only guard that decode_n stages
                 exactly one while_loop -> BENCH_serve.json
  dist           distributed operator family at 8 virtual devices:
                 measured (HLO-parsed) vs modeled (closed-form) collective
                 traffic per dist_* op, gated exactly in-run
                 -> BENCH_dist.json                                [8 devices]
"""
from __future__ import annotations

import argparse
import functools
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import dump_json, row, timeit  # noqa: E402
from repro.core import accum_dtype_for, scan  # noqa: E402
from repro.core.autotune import resolve_method  # noqa: E402
from repro.core.primitives import (compress, radix_sort, split,  # noqa: E402
                                   top_p_sample)

QUICK_LENS = [4096, 65536, 1 << 20]
FULL_LENS = [4096, 65536, 1 << 20, 1 << 23]
SMOKE_LENS = [2048, 16384]

# "auto" rows ride along in every sweep so tools/compare_bench.py can gate
# them against the per-row oracle (best measured concrete method); their
# derived column records what the tuning table resolved to.
OP_METHODS = ("vector", "matmul", "kernel", "auto")


def _resolved(op: str, n: int, dtype) -> str:
    """``;resolved=<m>`` derived-column suffix for a method="auto" row."""
    return f";resolved={resolve_method(op, n, dtype)}"


def fig3_single_scan(lens):
    """Paper Fig. 3: execution time of vector-only CumSum vs ScanU/ScanUL1."""
    for n in lens:
        x = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
        fns = {
            "vec_only": jax.jit(lambda a: jnp.cumsum(a)),
            "scanu": jax.jit(functools.partial(scan, method="matmul",
                                               variant="scanu", tile_s=128)),
            "scanul1": jax.jit(functools.partial(scan, method="matmul",
                                                 variant="scanul1", tile_s=128)),
        }
        base = None
        for name, fn in fns.items():
            t = timeit(fn, x)
            base = base or t
            row(f"fig3/{name}/n={n}", t,
                f"speedup_vs_vec={base / t:.2f}x;GB/s={8 * n / t / 1e9:.2f}")


def fig5_batched_ratio():
    """Paper Fig. 5: ScanUL1 vs ScanU time ratio across (batch, length)."""
    for batch in (4, 16, 64):
        for n in (1024, 4096, 16384):
            x = jnp.asarray(
                np.random.default_rng(1).standard_normal((batch, n)), jnp.float32)
            tu = timeit(jax.jit(functools.partial(
                scan, method="matmul", variant="scanu", tile_s=32)), x)
            tl = timeit(jax.jit(functools.partial(
                scan, method="matmul", variant="scanul1", tile_s=32)), x)
            row(f"fig5/ratio/b={batch}/n={n}", tl,
                f"scanul1_over_scanu={tl / tu:.3f}")


_MC_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, time, functools
import numpy as np, jax, jax.numpy as jnp
sys.path.insert(0, {src!r})
from repro.core import mcscan
from repro.utils.compat import make_mesh
mesh = make_mesh((8,), ("data",))
for spec in {specs!r}:
    n, s, dt = spec
    dtype = jnp.int8 if dt == "int8" else (jnp.bfloat16 if dt == "bf16" else jnp.float32)
    if dt == "int8":
        x = jnp.asarray(np.random.default_rng(0).integers(-3, 4, (1, n)), dtype)
    else:
        x = jnp.asarray(np.random.default_rng(0).standard_normal((1, n)), dtype)
    fn = jax.jit(lambda a: mcscan(a, mesh, "data", tile_s=s))
    out = fn(x); jax.block_until_ready(out)
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    t = float(np.median(ts))
    nbytes = x.dtype.itemsize * n + out.dtype.itemsize * n
    print(f"MC,{{n}},{{s}},{{dt}},{{t}},{{nbytes}}")
# copy baseline
for n in sorted(set(sp[0] for sp in {specs!r})):
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, n)), jnp.float32)
    fn = jax.jit(lambda a: a + 0.0)
    jax.block_until_ready(fn(x))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); jax.block_until_ready(fn(x))
        ts.append(time.perf_counter() - t0)
    print(f"COPY,{{n}},0,f32,{{float(np.median(ts))}},{{8 * n}}")
"""


def fig8_fig9_mcscan(lens):
    """Paper Figs. 8/9: multi-device MCScan bandwidth + int8 vs fp16 elems/s.

    Needs >1 device, so runs in a subprocess with 8 host devices.
    """
    specs = [(n, s, "f32") for n in lens for s in (32, 64, 128)]
    specs += [(lens[-1], 128, "bf16"), (lens[-1], 128, "int8")]
    code = _MC_SUB.format(src=os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")), specs=specs)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=1200)
    if r.returncode != 0:
        row("fig8/mcscan/ERROR", 0.0, r.stderr.strip()[-120:].replace(",", ";"))
        return
    elems = {}
    for line in r.stdout.splitlines():
        parts = line.strip().split(",")
        if parts[0] == "MC":
            n, s, dt, t, nb = int(parts[1]), int(parts[2]), parts[3], \
                float(parts[4]), int(parts[5])
            row(f"fig8/mcscan/n={n}/s={s}/{dt}", t,
                f"GB/s={nb / t / 1e9:.2f};GElems/s={n / t / 1e9:.3f}")
            elems[dt] = n / t / 1e9
        elif parts[0] == "COPY":
            n, t, nb = int(parts[1]), float(parts[4]), int(parts[5])
            row(f"fig8/copy/n={n}", t, f"GB/s={nb / t / 1e9:.2f}")
    if "int8" in elems and "bf16" in elems:
        row("fig9/int8_vs_fp16", 0.0,
            f"int8_GElems/s={elems['int8']:.3f};fp16_GElems/s={elems['bf16']:.3f};"
            f"ratio={elems['int8'] / max(elems['bf16'], 1e-9):.2f}x")


def fig10_compress(lens):
    """Paper Fig. 10: compress (scan-based) vs baseline masked-select."""
    for n in lens:
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        m = jnp.asarray(rng.random(n) < 0.5)
        # pinned: this section reproduces the paper's matmul-scan operator,
        # independent of what the tuning table would pick at this length
        ours = jax.jit(lambda a, f: compress(a, f, method="matmul")[0])
        base2 = jax.jit(lambda a, f: a[jnp.nonzero(f, size=n)[0]])
        t_ours = timeit(ours, x, m)
        t_nz = timeit(base2, x, m)
        row(f"fig10/compress/n={n}", t_ours,
            f"GB/s={8 * n / t_ours / 1e9:.2f};baseline_nonzero_us={t_nz * 1e6:.1f}")


def fig11_radix_sort(lens):
    """Paper Fig. 11: fp16 radix sort (scan splits) vs jnp.sort baseline.

    Pinned to ``bits_per_pass=1`` — this figure reproduces the paper's
    per-bit formulation; the multi-bit trajectory lives in the ``sort``
    section (BENCH_sort.json).
    """
    for n in lens:
        x = jnp.asarray(np.random.default_rng(3).standard_normal(n), jnp.float16)
        t_ours = timeit(jax.jit(lambda a: radix_sort(
            a, method="matmul", bits_per_pass=1)[0]), x)
        t_base = timeit(jax.jit(lambda a: jnp.sort(a)), x)
        row(f"fig11/radix_sort/n={n}", t_ours,
            f"baseline_us={t_base * 1e6:.1f};ratio={t_base / t_ours:.2f}x")


def fig12_batched_bandwidth():
    """Paper Fig. 12: batched scan bandwidth vs batch size (len 65K)."""
    n = 65536
    for batch in (1, 4, 16, 64):
        x = jnp.asarray(np.random.default_rng(4).standard_normal((batch, n)),
                        jnp.float32)
        for s in (16, 32, 64, 128):
            t = timeit(jax.jit(functools.partial(
                scan, method="matmul", variant="scanu", tile_s=s)), x)
            row(f"fig12/batched/b={batch}/s={s}", t,
                f"GB/s={8 * batch * n / t / 1e9:.2f}")


def fig13_top_p(quick=True):
    """Paper Fig. 13: llama3-style top-p sampling, baseline vs scan-based.

    Pinned to ``bits_per_pass=1`` so the row's ``scans_per_batch=17`` (16
    sort splits + 1 CDF scan) keeps meaning the paper's per-bit operator;
    the multi-bit trajectory lives in the ``sort`` section.
    """
    vocab = 32768 if quick else 131072
    for batch in (1, 4, 16):
        logits = jnp.asarray(
            np.random.default_rng(5).standard_normal((batch, vocab)) * 3,
            jnp.float32)
        key = jax.random.PRNGKey(0)
        ours = jax.jit(lambda l, k: top_p_sample(l, k, p=0.9, method="matmul",
                                                 sort_method="radix",
                                                 bits_per_pass=1))
        base = jax.jit(lambda l, k: top_p_sample(l, k, p=0.9, method="matmul",
                                                 sort_method="xla"))
        t_ours = timeit(ours, logits, key, repeats=3, warmup=1)
        t_base = timeit(base, logits, key, repeats=3, warmup=1)
        row(f"fig13/top_p/b={batch}/v={vocab}", t_ours,
            f"baseline_us={t_base * 1e6:.1f};scans_per_batch=17")


# ---------------------------------------------------------------------------
# Blocked pipeline sweep: large-N bandwidth vs memcpy (paper §4 headline)
# ---------------------------------------------------------------------------


def scan_pipeline_sweep(lens, smoke=False):
    """Paper §4 blocked pipeline: achieved bytes/s as a fraction of memcpy.

    The paper's headline multi-core metric is scan bandwidth relative to a
    memory copy (74.9% on 8 Ascend cores).  For each length and dtype we time
    a jitted copy as the roofline, then every scan method; ``memcpy_frac`` in
    the derived column (and in BENCH_scan_pipeline.json) is
    ``(scan bytes moved / t) / (copy bytes moved / t_copy)``.  Scan moves
    ``n * (in_itemsize + accum_itemsize)`` bytes — the accumulation dtype
    (int8 -> int32, bf16 -> f32) widens the write side.
    """
    dts = {"float32": jnp.float32} if smoke else \
        {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "int8": jnp.int8}
    methods = ("vector", "matmul", "kernel", "blocked", "auto")
    s = 32 if smoke else 128
    for dt_name, dt in dts.items():
        for n in lens:
            rng = np.random.default_rng(0)
            if dt_name == "int8":
                x = jnp.asarray(rng.integers(-3, 4, n), dt)
            else:
                x = jnp.asarray(rng.standard_normal(n), dt)
            cp = jax.jit(lambda a: a + jnp.zeros((), a.dtype))
            t_copy = timeit(cp, x, repeats=3, warmup=1)
            copy_bw = 2 * x.nbytes / t_copy
            row(f"scan_pipeline/memcpy/{dt_name}/n={n}", t_copy,
                f"GB/s={copy_bw / 1e9:.2f};memcpy_frac=1.000")
            nbytes = x.nbytes + n * jnp.dtype(accum_dtype_for(dt)).itemsize
            for m in methods:
                fn = jax.jit(functools.partial(scan, method=m, tile_s=s))
                t = timeit(fn, x, repeats=3, warmup=1)
                bw = nbytes / t
                extra = _resolved("scan", n, dt) if m == "auto" else ""
                row(f"scan_pipeline/{m}/{dt_name}/n={n}", t,
                    f"GB/s={bw / 1e9:.2f};memcpy_frac={bw / copy_bw:.3f}"
                    f"{extra}")


# ---------------------------------------------------------------------------
# sort: radix-2^k sweep — method × dtype × bits_per_pass (ISSUE 3 tentpole)
# ---------------------------------------------------------------------------

SORT_BITS_PER_PASS = (1, 2, 4, 8)
_SORT_DTYPES = {  # dtype -> (sort bits, encoded key bytes)
    "float32": (32, 4),
    "bfloat16": (16, 2),
    "int8": (8, 1),
}


def _count_radix_pass_launches(fn, *args) -> int:
    """Count fused radix-pass ``pallas_call`` launches in ``fn``'s jaxpr.

    Walks the jaxpr recursively (pjit bodies included) and counts every
    pallas_call whose kernel name contains ``radix_pass`` — the guard that a
    ``bits_per_pass=k`` sort really executes ``ceil(bits / k)`` fused passes
    instead of silently falling back to per-bit splits.
    """
    def walk(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                nm = eqn.params.get("name_and_src_info",
                                    eqn.params.get("name", ""))
                if "radix_pass" in str(nm):
                    total += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):       # ClosedJaxpr param
                    total += walk(v.jaxpr)
                elif hasattr(v, "eqns"):      # raw Jaxpr param
                    total += walk(v)
        return total

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def sort_pass_count_guard():
    """Assert the fused sort's launch count is exactly ``ceil(bits / k)``.

    Trace-only (no execution), so it is cheap enough to run on every sweep;
    a mismatch aborts the benchmark run with a non-zero exit — this is the
    bench-smoke CI assertion against silent per-bit fallback.
    """
    x32 = jnp.asarray(np.random.default_rng(0).standard_normal(256),
                      jnp.float32)
    x16 = x32.astype(jnp.bfloat16)
    for dt_name, x, bits in (("float32", x32, 32), ("bfloat16", x16, 16)):
        for k in SORT_BITS_PER_PASS:
            want = -(-bits // k)
            got = _count_radix_pass_launches(
                lambda a, k=k: radix_sort(a, method="kernel",
                                          bits_per_pass=k)[0], x)
            row(f"sort/pass_count/{dt_name}/k={k}", 0.0,
                f"passes={got};expected={want}")
            if got != want:
                raise SystemExit(
                    f"sort pass-count guard: {dt_name} bits_per_pass={k} "
                    f"executed {got} fused passes, expected {want}")


def sort_sweep(lens):
    """Radix-2^k sort sweep: method × dtype × bits_per_pass -> BENCH_sort.json.

    ``passes`` is ``ceil(bits / k)``; ``bytes_moved`` models the HBM traffic
    of the chained passes — every pass reads and writes both the keys and the
    int32 permutation, i.e. ``passes * n * (key_bytes + 4) * 2`` — so
    ``bits_per_pass=4`` shows the ~4x traffic cut over per-bit splits on the
    same row.  The trace-only pass-count guard runs first.
    """
    sort_pass_count_guard()
    methods = ("vector", "matmul", "kernel", "auto")
    for dt_name, (bits, key_bytes) in _SORT_DTYPES.items():
        for n in lens:
            x = _op_payload(dt_name, n, seed=6)
            for m in methods:
                base = None
                for k in SORT_BITS_PER_PASS:
                    passes = -(-bits // k)
                    bytes_moved = passes * n * (key_bytes + 4) * 2
                    fn = jax.jit(lambda a, m=m, k=k: radix_sort(
                        a, method=m, bits_per_pass=k)[0])
                    t = timeit(fn, x, repeats=3, warmup=1)
                    base = base or t
                    extra = _resolved("radix_sort", n, dt_name) \
                        if m == "auto" else ""
                    row(f"sort/{dt_name}/n={n}/{m}/k={k}", t,
                        f"passes={passes};bytes_moved={bytes_moved};"
                        f"GB/s={bytes_moved / t / 1e9:.2f};"
                        f"speedup_vs_k1={base / t:.2f}x{extra}")


# ---------------------------------------------------------------------------
# segscan: segmented scan over ragged packed batches (ISSUE 4 tentpole)
# ---------------------------------------------------------------------------


def segscan_sweep(smoke=False):
    """Segmented scan: segment-count × mean-segment-length × method sweep.

    Ragged offsets are drawn deterministically (uniform cuts, so empty and
    tiny segments occur); every method scans the same fp32 packed batch and
    the derived column reports throughput plus ``pad_waste`` — the fraction of
    extra elements a dense ``(segments, max_len)`` padding of the same batch
    would read/write, i.e. the traffic the packed layout avoids.
    """
    from repro.core.segmented import segment_scan
    methods = ("vector", "matmul", "kernel", "blocked", "auto")
    s = 16 if smoke else 128
    grid = ((4, 128), (16, 256)) if smoke else \
        ((8, 512), (64, 1024), (512, 2048))
    for num_segs, mean_len in grid:
        n = num_segs * mean_len
        rng = np.random.default_rng(7)
        cuts = np.sort(rng.integers(0, n + 1, num_segs - 1))
        offsets = jnp.asarray(np.concatenate([[0], cuts, [n]]), jnp.int32)
        lens = np.diff(np.asarray(offsets))
        pad_waste = (num_segs * int(lens.max()) - n) / n
        x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        base = None
        for m in methods:
            fn = jax.jit(lambda v, o, m=m: segment_scan(v, o, method=m,
                                                        tile_s=s))
            t = timeit(fn, x, offsets, repeats=3, warmup=1)
            base = base or t
            extra = _resolved("segment_scan", n, jnp.float32) \
                if m == "auto" else ""
            row(f"segscan/{m}/S={num_segs}/L={mean_len}", t,
                f"n={n};GB/s={8 * n / t / 1e9:.2f};"
                f"pad_waste={pad_waste:.2f};"
                f"speedup_vs_vector={base / t:.2f}x{extra}")


# ---------------------------------------------------------------------------
# linrec: linear-recurrence scan over gated decays (ISSUE 5 tentpole)
# ---------------------------------------------------------------------------


def linrec_sweep(smoke=False):
    """Linear recurrence ``y = a*y_prev + b``: S × L × method × dtype sweep.

    Payloads model the recurrent-decode workload: multipliers are gated
    decays ``a = exp(-|g|) ∈ (0, 1]``, inputs Gaussian.  Every method scans
    the same batch; the derived column reports throughput (three streams:
    read ``a``, read ``b``, write ``y`` in the accumulation dtype) and the
    speedup over the affine-pair ``associative_scan`` vector baseline.
    """
    from repro.core.linrec import linear_scan, linrec_accum_dtype_for
    methods = ("vector", "matmul", "kernel", "blocked", "auto")
    dts = {"float32": jnp.float32} if smoke else \
        {"float32": jnp.float32, "bfloat16": jnp.bfloat16}
    s = 16 if smoke else 128
    grid = ((2, 1024), (8, 4096)) if smoke else \
        ((4, 16384), (16, 65536))
    for num_rows, length in grid:
        rng = np.random.default_rng(8)
        a_np = np.exp(-np.abs(rng.standard_normal((num_rows, length))) * 0.05)
        b_np = rng.standard_normal((num_rows, length))
        for dt_name, dt in dts.items():
            a = jnp.asarray(a_np, dt)
            b = jnp.asarray(b_np, dt)
            n = num_rows * length
            nbytes = 2 * a.dtype.itemsize * n + \
                jnp.dtype(linrec_accum_dtype_for(dt)).itemsize * n
            base = None
            for m in methods:
                fn = jax.jit(lambda a, b, m=m: linear_scan(a, b, method=m,
                                                           tile_s=s))
                t = timeit(fn, a, b, repeats=3, warmup=1)
                base = base or t
                extra = _resolved("linear_scan", length, dt) \
                    if m == "auto" else ""
                row(f"linrec/{m}/{dt_name}/S={num_rows}/L={length}", t,
                    f"n={n};GB/s={nbytes / t / 1e9:.2f};"
                    f"speedup_vs_vector={base / t:.2f}x{extra}")


# ---------------------------------------------------------------------------
# precision: fp16/bf16 matmul-engine scans + ulp accuracy (ISSUE 7 tentpole)
# ---------------------------------------------------------------------------


def precision_sweep(smoke=False):
    """Precision axis sweep: time + max-ulp per op × engine method × precision.

    Every row runs one scan-family op at ``precision in ("highest",
    "compensated", "fast")`` on the same payload and scores the result against
    the fp64 sequential reference of :mod:`repro.analysis.ulp` — the derived
    column carries ``max_ulp`` (in fp32 ulps at the conditioning scale) and
    ``ulp_bound`` (the documented contract), which ``tools/compare_bench.py``
    gates: ``max_ulp <= ulp_bound`` always, and bounded drift vs baseline.

    ``time_vs_highest`` records the speed ratio against the fp32 path of the
    same method.  On the CPU test backend XLA contracts fp16/bf16 through the
    same fp32 units, so the split's extra products make compensated ~parity to
    ~3x slower here; on an fp16-native matrix engine (the paper's target) the
    two-to-three fp16 products replace one fp32 product at twice the MAC rate
    — the documented-speedup column is measured, not modelled, so the CPU
    baseline records parity and a hardware runner records the gain.
    """
    from repro.analysis import ulp
    from repro.core.linrec import linear_scan
    from repro.core.segmented import segment_scan
    methods = ("matmul", "kernel", "blocked")
    precisions = ("highest", "compensated", "fast")
    s = 32 if smoke else 128
    sweep_lens = (2048,) if smoke else (16384, 65536)
    rng = np.random.default_rng(9)
    for n in sweep_lens:
        x = np.asarray(rng.standard_normal(n), np.float32)
        a = np.asarray(np.exp(-np.abs(rng.standard_normal((4, n))) * 0.05),
                       np.float32)
        b = np.asarray(rng.standard_normal((4, n)), np.float32)
        cuts = np.sort(rng.integers(0, n + 1, max(1, n // 512)))
        off = np.concatenate([[0], cuts, [n]]).astype(np.int32)
        cases = (
            ("scan",
             lambda m, p: jax.jit(functools.partial(
                 scan, method=m, precision=p, tile_s=s)),
             (jnp.asarray(x),), ulp.scan_ref(x), ulp.scan_scale(x)),
            ("linrec",
             lambda m, p: jax.jit(lambda u, v: linear_scan(
                 u, v, method=m, precision=p, tile_s=s)),
             (jnp.asarray(a), jnp.asarray(b)),
             ulp.linrec_ref(a, b), ulp.linrec_scale(a, b)),
            ("segscan",
             lambda m, p: jax.jit(lambda v, o: segment_scan(
                 v, o, method=m, precision=p, tile_s=s)),
             (jnp.asarray(x), jnp.asarray(off)),
             ulp.segment_scan_ref(x, off), ulp.segment_scan_scale(x, off)),
        )
        for op, make, args_, ref, scale in cases:
            base = None
            for m in methods:
                for p in precisions:
                    fn = make(m, p)
                    t = timeit(fn, *args_, repeats=3, warmup=1)
                    if p == "highest":
                        base = t
                    mu = ulp.max_ulp(np.asarray(fn(*args_)), ref, scale)
                    row(f"precision/{op}/{m}/{p}/n={n}", t,
                        f"n={n};max_ulp={mu:.2f};"
                        f"ulp_bound={ulp.ulp_bound(p, n):.1f};"
                        f"time_vs_highest={t / base:.2f}x")


# ---------------------------------------------------------------------------
# Operator benchmarks: split / sort / top-p across methods and dtypes
# (tracks the fused-kernel trajectory, not just raw scan — ISSUE 1 tentpole)
# ---------------------------------------------------------------------------

_OP_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "int8": jnp.int8}


def _op_payload(dtype_name, shape, seed=0):
    rng = np.random.default_rng(seed)
    if dtype_name == "int8":
        return jnp.asarray(rng.integers(-128, 128, shape), jnp.int8)
    return jnp.asarray(rng.standard_normal(shape), _OP_DTYPES[dtype_name])


def ops_split(n: int):
    """SplitInd across methods × payload dtypes (kernel = fused Pallas launch)."""
    f = jnp.asarray(np.random.default_rng(1).random(n) < 0.5)
    for dt in _OP_DTYPES:
        x = _op_payload(dt, n)
        base = None
        for m in OP_METHODS:
            fn = jax.jit(lambda a, fl, m=m: split(a, fl, method=m)[0])
            t = timeit(fn, x, f, repeats=3, warmup=1)
            base = base or t
            extra = _resolved("split", n, dt) if m == "auto" else ""
            row(f"ops/split/{dt}/n={n}/{m}", t,
                f"speedup_vs_vector={base / t:.2f}x{extra}")


def ops_sort(n: int, dtypes=("bfloat16", "float32")):
    """Radix sort as shipped (default ``bits_per_pass=4``) across methods × key widths."""
    for dt in dtypes:
        x = _op_payload(dt, n, seed=2)
        bits = 16 if dt == "bfloat16" else 32
        base = None
        for m in OP_METHODS:
            fn = jax.jit(lambda a, m=m: radix_sort(a, method=m)[0])
            t = timeit(fn, x, repeats=3, warmup=1)
            base = base or t
            extra = _resolved("radix_sort", n, dt) if m == "auto" else ""
            row(f"ops/sort/{dt}/n={n}/{m}", t,
                f"bits={bits};speedup_vs_vector={base / t:.2f}x{extra}")


def ops_top_p(vocab: int, batch: int = 4):
    """Nucleus sampling across methods (kernel = fused radix + one-launch tail)."""
    logits = jnp.asarray(
        np.random.default_rng(3).standard_normal((batch, vocab)) * 3,
        jnp.float32)
    key = jax.random.PRNGKey(0)
    base = None
    for m in OP_METHODS:
        fn = jax.jit(lambda l, k, m=m: top_p_sample(l, k, p=0.9, method=m))
        t = timeit(fn, logits, key, repeats=3, warmup=1)
        base = base or t
        extra = _resolved("top_p_sample", vocab, jnp.float32) \
            if m == "auto" else ""
        row(f"ops/top_p/b={batch}/v={vocab}/{m}", t,
            f"speedup_vs_vector={base / t:.2f}x{extra}")


def ops_operators(smoke: bool):
    n = 2048 if smoke else 16384
    ops_split(n)
    ops_sort(n // 2 if smoke else n, dtypes=("bfloat16",) if smoke
             else ("bfloat16", "float32"))
    ops_top_p(1024 if smoke else 16384, batch=2 if smoke else 4)


# ---------------------------------------------------------------------------
# serve: continuous batching under Poisson arrivals (ISSUE 9 tentpole)
# ---------------------------------------------------------------------------


def serve_sweep(smoke=False):
    """Continuous-batching serve sweep under Poisson arrivals -> BENCH_serve.json.

    A seeded ragged request trace is served by ``ContinuousEngine`` (paged KV
    + in-graph ``decode_n``), against the dense sequential baseline (each
    request alone through ``ServeEngine.generate`` — the ``kv_layout="dense"``
    path).  The trace-only launch guard asserts ``decode_n`` stages exactly
    one ``while_loop`` (no per-token dispatch) and aborts the run otherwise —
    the bench-smoke CI gate.  With ``eos_id=None`` every schedule-derived
    metric (tokens, steps, peak pages, p50/p99 step latencies) is a pure
    function of the seeded trace — independent of model numerics — so
    ``tools/compare_bench.py`` gates them exactly; tokens/s stays a timing.
    """
    import time

    from repro.models.model import build_model, get_config
    from repro.serving.engine import ServeEngine
    from repro.serving.scheduler import (ContinuousEngine, count_while_loops,
                                         poisson_trace)

    cfg = get_config("llama3-8b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    page_size = 8
    grids = [(3, 13, 4, 0.4, 8)] if smoke else \
        [(4, 25, 8, 0.5, 16), (8, 49, 8, 1.0, 24)]
    for max_batch, n_pages, tick, rate, n_reqs in grids:
        eng = ContinuousEngine(cfg, params, max_batch=max_batch,
                               page_size=page_size, n_pages=n_pages,
                               max_len=32, sampler="greedy", tick_tokens=tick)
        n_while = count_while_loops(eng.decode_n_jaxpr(tick))
        row(f"serve/trace_guard/decode_n/B={max_batch}", 0.0,
            f"while_loops={n_while};expected=1")
        if n_while != 1:
            raise SystemExit(
                f"serve launch guard: decode_n staged {n_while} while_loops, "
                "expected exactly 1 (multi-token decode must be one in-graph "
                "loop, not per-token dispatch)")
        trace = poisson_trace(n_reqs, rate=rate, vocab_size=cfg.vocab_size,
                              seed=17, prompt_len=(3, 10), max_new=(2, 8))
        eng.run(trace)                  # warmup: compile prefill/decode
        t0 = time.perf_counter()
        res = eng.run(trace)
        dt = time.perf_counter() - t0
        st = res["stats"]
        lat = np.asarray(sorted(r["per_token_latency_steps"]
                                for r in res["requests"].values()))
        row(f"serve/continuous/B={max_batch}/pages={n_pages}/rate={rate}", dt,
            f"tokens={st['total_tokens']};reqs={st['reqs']};"
            f"steps={st['steps']};peak_pages={st['peak_pages']};"
            f"util={st['peak_util']:.3f};"
            f"p50_steps={np.percentile(lat, 50):.3f};"
            f"p99_steps={np.percentile(lat, 99):.3f};"
            f"tokens_per_s={st['total_tokens'] / dt:.1f}")
        dense = ServeEngine(cfg, params, max_len=eng.n_blocks * page_size,
                            sampler="greedy")
        for r in trace:                 # warmup compiles per prompt length
            dense.generate({"tokens": jnp.asarray(r.tokens)[None]},
                           r.max_new_tokens, jnp.asarray(r.key))
        t0 = time.perf_counter()
        total = 0
        for r in trace:
            total += dense.generate(
                {"tokens": jnp.asarray(r.tokens)[None]}, r.max_new_tokens,
                jnp.asarray(r.key)).shape[1]
        dt_d = time.perf_counter() - t0
        row(f"serve/dense_sequential/B={max_batch}/rate={rate}", dt_d,
            f"tokens={total};reqs={n_reqs};"
            f"tokens_per_s={total / dt_d:.1f};"
            f"continuous_speedup={dt_d / dt:.2f}x")


_DIST_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, time
import numpy as np, jax, jax.numpy as jnp
sys.path.insert(0, {src!r})
from repro.analysis.roofline import summarize_collectives
from repro.core import (dist_linear_scan, dist_radix_sort, dist_segment_scan,
                        dist_top_p_sample)
from repro.utils.compat import make_mesh
rng = np.random.default_rng(0)
for op, d, n, bpp in {specs!r}:
    mesh = make_mesh((d,), ("data",))
    if op == "dist_sort":
        x = jnp.asarray(rng.normal(size=(2, n)), jnp.bfloat16)
        fn, args = (lambda v: dist_radix_sort(
            v, mesh, "data", method="matmul", tile_s=32,
            bits_per_pass=bpp)), (x,)
        dt = "bfloat16"
    elif op == "dist_top_p_sample":
        lg = jnp.asarray(rng.normal(size=(2, n)) * 3, jnp.float32)
        fn, args = (lambda v, k: dist_top_p_sample(
            v, k, mesh, "data", p=0.9, method="matmul", tile_s=32,
            bits_per_pass=bpp)), (lg, jax.random.PRNGKey(0))
        dt = "float32"
    elif op == "dist_linear_scan":
        a = jnp.asarray(rng.uniform(0.8, 1.2, size=(2, n)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(2, n)), jnp.float32)
        fn, args = (lambda u, v: dist_linear_scan(
            u, v, mesh, "data", method="matmul", tile_s=32)), (a, b)
        dt = "float32"
    else:
        xs = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        off = jnp.asarray([0, n // 3, n], jnp.int32)
        fn, args = (lambda v, o: dist_segment_scan(
            v, o, mesh, "data", method="matmul", tile_s=32)), (xs, off)
        dt = "float32"
    compiled = jax.jit(fn).lower(*args).compile()
    meas = summarize_collectives(compiled.as_text())
    jax.block_until_ready(compiled(*args))
    ts = []
    for _ in range(3):
        t0 = time.perf_counter(); jax.block_until_ready(compiled(*args))
        ts.append(time.perf_counter() - t0)
    print(f"DIST,{{op}},{{dt}},{{d}},{{n}},{{bpp}},{{float(np.median(ts))}},"
          f"{{meas['collective_count']}},{{meas['operand_bytes']}}")
"""


def dist_sweep(smoke=False):
    """Distributed operator family: measured-vs-modeled traffic -> BENCH_dist.json.

    Every ``dist_*`` operator is lowered at 8 (and, non-smoke, 2) virtual
    host devices; the post-SPMD HLO is parsed for collectives
    (:func:`repro.analysis.roofline.summarize_collectives`) and compared —
    in-run, aborting on mismatch — against the closed forms of
    :func:`repro.analysis.collectives.modeled_dist_traffic`
    (docs/distributed.md §Traffic).  Collective counts and operand bytes are
    both shape-derived, so the gate is **exact**: the committed
    ``bytes_measured`` must equal ``bytes_modeled`` on every row, and
    ``tools/compare_bench.py`` re-gates all three derived columns against the
    committed baseline.  Timings ride along informationally (CPU backend).
    """
    from repro.analysis.collectives import modeled_dist_traffic
    n = 256 if smoke else 2048
    specs = [("dist_sort", 8, n, 8), ("dist_top_p_sample", 8, n, 8),
             ("dist_linear_scan", 8, n, 8), ("dist_segment_scan", 8, n, 8)]
    if not smoke:
        specs += [("dist_sort", 2, n, 4), ("dist_linear_scan", 2, n, 4)]
    code = _DIST_SUB.format(src=os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")), specs=specs)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=1800)
    if r.returncode != 0:
        raise SystemExit(f"dist sweep subprocess failed:\n{r.stderr[-2000:]}")
    for line in r.stdout.splitlines():
        parts = line.strip().split(",")
        if parts[0] != "DIST":
            continue
        op, dt, d, nn, bpp, t, mc, mb = parts[1], parts[2], int(parts[3]), \
            int(parts[4]), int(parts[5]), float(parts[6]), int(parts[7]), \
            float(parts[8])
        mod = modeled_dist_traffic(op, d=d, n=nn, batch=1 if op ==
                                   "dist_segment_scan" else 2, dtype=dt,
                                   bits_per_pass=bpp)
        row(f"dist/{op}/matmul/{dt}/d={d}/n={nn}", t,
            f"collective_count={mc};bytes_measured={mb:.0f};"
            f"bytes_modeled={mod['operand_bytes']:.0f}")
        if mc != mod["collective_count"] or mb != mod["operand_bytes"]:
            raise SystemExit(
                f"dist traffic guard: {op} d={d} n={nn} measured "
                f"{mc} collectives / {mb:.0f} operand bytes, model says "
                f"{mod['collective_count']} / {mod['operand_bytes']:.0f} — "
                "the lowered HLO no longer matches docs/distributed.md "
                "§Traffic")


def guards_identity_guard():
    """Assert guards-off traces are byte-identical to ``guards_disabled``.

    Rule 10's zero-overhead contract: with ``REPRO_CHECKS`` unset, every
    guarded operator must stage the exact jaxpr it staged before the guards
    layer existed.  Trace-only (no execution); a mismatch aborts the run with
    a non-zero exit — the bench-smoke CI gate against guard ops leaking into
    the default trace.
    """
    import re

    from repro.core import guards
    from repro.core.linrec import linear_scan
    from repro.core.primitives import weighted_sample
    from repro.core.segmented import segment_scan, segment_top_p_sample

    x = jnp.asarray(np.random.default_rng(0).standard_normal(5), jnp.float32)
    off = jnp.asarray([0, 3, 5], jnp.int32)
    cases = {
        "scan": lambda v: scan(v),
        "linrec": lambda v: linear_scan(v, v),
        "segment_scan": lambda v: segment_scan(v, off),
        "weighted_sample": lambda v: weighted_sample(
            v, None, u=jnp.asarray(0.5)),
        "top_p": lambda v: top_p_sample(v[None], None, p=0.9,
                                        u=jnp.asarray([[0.5]])),
        "segment_top_p": lambda v: segment_top_p_sample(
            v, off, p=0.9, u=jnp.asarray([[0.5], [0.5]])),
    }

    def trace(fn):
        return re.sub(r"0x[0-9a-f]+", "", str(jax.make_jaxpr(fn)(x)))

    for name, fn in cases.items():
        with guards.checks(False):
            guarded = trace(fn)
        with guards.guards_disabled():
            bare = trace(fn)
        same = guarded == bare
        row(f"guards/jaxpr_identity/{name}", 0.0, f"identical={same}")
        if not same:
            raise SystemExit(
                f"guards jaxpr-identity guard: {name} traces differently "
                "with the guards layer active (checks off) vs disabled")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger sizes")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + fast sections only (CI)")
    ap.add_argument("--only", default=None,
                    help="comma list of section ids, e.g. fig3,ops")
    ap.add_argument("--json-out", default=None, metavar="DIR",
                    help="write BENCH_<section>.json row files to DIR")
    args = ap.parse_args()
    lens = SMOKE_LENS if args.smoke else (FULL_LENS if args.full else QUICK_LENS)
    sections = {
        "fig3": lambda: fig3_single_scan(lens),
        "fig5": fig5_batched_ratio,
        "fig8": lambda: fig8_fig9_mcscan(lens),
        "fig10": lambda: fig10_compress(lens[:2]),
        "fig11": lambda: fig11_radix_sort(lens[:2]),
        "fig12": fig12_batched_bandwidth,
        "fig13": lambda: fig13_top_p(quick=not args.full),
        "scan_pipeline": lambda: scan_pipeline_sweep(lens, smoke=args.smoke),
        "sort": lambda: sort_sweep([512] if args.smoke else lens[:2]),
        "segscan": lambda: segscan_sweep(smoke=args.smoke),
        "linrec": lambda: linrec_sweep(smoke=args.smoke),
        "precision": lambda: precision_sweep(smoke=args.smoke),
        "ops": lambda: ops_operators(smoke=args.smoke),
        "serve": lambda: serve_sweep(smoke=args.smoke),
        "dist": lambda: dist_sweep(smoke=args.smoke),
        "guards": guards_identity_guard,
    }
    only = set(args.only.split(",")) if args.only else None
    if args.smoke and only is None:
        # fast sections (sort carries the pass-count guard, serve the
        # while-loop launch guard, guards the jaxpr-identity guard, dist —
        # the one subprocess section — the measured-vs-modeled traffic guard)
        only = {"fig3", "fig10", "fig11", "scan_pipeline", "sort", "segscan",
                "linrec", "precision", "ops", "serve", "dist", "guards"}
    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if only and name not in only:
            continue
        fn()
    if args.json_out:
        for p in dump_json(args.json_out):
            print(f"# wrote {p}", flush=True)


if __name__ == "__main__":
    main()
