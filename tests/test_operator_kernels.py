"""Fused operator kernels (split_mm) vs the unfused paths.

Acceptance: ``method="kernel"`` is bit-identical to ``method="vector"`` for
split / radix_sort / topk / top_p_sample on CPU interpret mode, across
fp32 / bf16 / int8 payloads and odd lengths (non-multiples of s²).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import compress, radix_sort, sort, split, top_p_sample, topk
from repro.core.primitives import dispatch

S = 16                       # kernel mask-scan row width (small: interpret speed)
ODD_LENS = [5, 37, 333]      # none is a multiple of S² = 256


def _payload(dtype, n, seed):
    rng = np.random.default_rng(seed)
    if dtype == "int8":
        return jnp.asarray(rng.integers(-128, 128, n), jnp.int8)
    return jnp.asarray(rng.standard_normal(n), dtype)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("n", ODD_LENS)
def test_split_parity(dtype, n):
    x = _payload(dtype, n, n)
    f = jnp.asarray(np.random.default_rng(n + 1).random(n) < 0.4)
    zv, iv, cv = split(x, f, method="vector", tile_s=S)
    zk, ik, ck = split(x, f, method="kernel", tile_s=S)
    np.testing.assert_array_equal(np.asarray(zv), np.asarray(zk))
    np.testing.assert_array_equal(np.asarray(iv), np.asarray(ik))
    assert int(cv) == int(ck)


def test_split_parity_batched():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 77)), jnp.float32)
    f = jnp.asarray(rng.random((4, 77)) < 0.5)
    zv, iv, cv = split(x, f, method="vector", tile_s=S)
    zk, ik, ck = split(x, f, method="kernel", tile_s=S)
    np.testing.assert_array_equal(np.asarray(zv), np.asarray(zk))
    np.testing.assert_array_equal(np.asarray(iv), np.asarray(ik))
    np.testing.assert_array_equal(np.asarray(cv), np.asarray(ck))


def test_split_all_true_all_false_shorter_than_s():
    for flags in (np.ones(5, bool), np.zeros(5, bool)):
        x = jnp.asarray(np.arange(5), jnp.float32)
        zv, iv, cv = split(x, jnp.asarray(flags), method="vector", tile_s=S)
        zk, ik, ck = split(x, jnp.asarray(flags), method="kernel", tile_s=S)
        np.testing.assert_array_equal(np.asarray(zv), np.asarray(zk))
        np.testing.assert_array_equal(np.asarray(iv), np.asarray(ik))
        assert int(cv) == int(ck)


def test_compress_parity():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(201), jnp.float32)
    m = jnp.asarray(rng.random(201) < 0.3)
    vv, cv = compress(x, m, method="vector", tile_s=S)
    vk, ck = compress(x, m, method="kernel", tile_s=S)
    np.testing.assert_array_equal(np.asarray(vv), np.asarray(vk))
    assert int(cv) == int(ck)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
@pytest.mark.parametrize("n", [37, 333])
@pytest.mark.parametrize("bits_per_pass", [1, 4])
def test_radix_sort_parity(dtype, n, bits_per_pass):
    x = _payload(dtype, n, 7 * n)
    vv, iv = radix_sort(x, method="vector", tile_s=S,
                        bits_per_pass=bits_per_pass)
    vk, ik = radix_sort(x, method="kernel", tile_s=S,
                        bits_per_pass=bits_per_pass)
    np.testing.assert_array_equal(np.asarray(vv), np.asarray(vk))
    np.testing.assert_array_equal(np.asarray(iv), np.asarray(ik))


def test_radix_sort_kernel_correct_vs_numpy():
    rng = np.random.default_rng(3)
    x = rng.standard_normal(300).astype(np.float32)
    v, idx = radix_sort(jnp.asarray(x), method="kernel", tile_s=S)
    np.testing.assert_array_equal(np.asarray(v), np.sort(x, kind="stable"))
    np.testing.assert_array_equal(x[np.asarray(idx)], np.asarray(v))


def test_radix_sort_descending_and_batched_kernel():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((3, 129)), jnp.bfloat16)
    vv, iv = sort(x, descending=True, method="vector", tile_s=S)
    vk, ik = sort(x, descending=True, method="kernel", tile_s=S)
    np.testing.assert_array_equal(np.asarray(vv.astype(jnp.float32)),
                                  np.asarray(vk.astype(jnp.float32)))
    np.testing.assert_array_equal(np.asarray(iv), np.asarray(ik))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_topk_parity(dtype):
    x = _payload(dtype, 211, 11)
    vv, iv = topk(x, 9, method="vector", tile_s=S)
    vk, ik = topk(x, 9, method="kernel", tile_s=S)
    np.testing.assert_array_equal(np.asarray(vv), np.asarray(vk))
    np.testing.assert_array_equal(np.asarray(iv), np.asarray(ik))


@pytest.mark.parametrize("n", [100, 257])
def test_top_p_parity(n):
    rng = np.random.default_rng(n)
    logits = jnp.asarray(rng.standard_normal((3, n)) * 2, jnp.float32)
    for i in range(3):
        key = jax.random.PRNGKey(i)
        tv = top_p_sample(logits, key, p=0.9, method="vector", tile_s=S)
        tk = top_p_sample(logits, key, p=0.9, method="kernel", tile_s=S)
        np.testing.assert_array_equal(np.asarray(tv), np.asarray(tk))


def test_top_p_kernel_restricts_to_nucleus():
    logits = jnp.asarray(np.r_[10.0, np.zeros(63)], jnp.float32)[None, :]
    keys = jax.random.split(jax.random.PRNGKey(1), 25)
    toks = np.asarray(jax.vmap(
        lambda k: top_p_sample(logits, k, p=0.5, method="kernel",
                               tile_s=S))(keys))
    assert np.all(toks == 0)


def test_dispatch_rejects_unknown_method():
    x = jnp.zeros(8)
    f = jnp.zeros(8, bool)
    with pytest.raises(ValueError):
        split(x, f, method="cube")
    with pytest.raises(ValueError):
        dispatch("split", "nope")
    with pytest.raises(ValueError):
        dispatch("no_such_op", "kernel")


def test_serving_engine_kernel_sampler():
    """The fused sampler slots into ServeEngine and matches the scan sampler."""
    from repro.models.model import get_config
    from repro.serving.engine import ServeEngine

    cfg = get_config("llama3-8b", smoke=True)
    key = jax.random.PRNGKey(0)
    eng_scan = ServeEngine(cfg, None, sampler="topp_scan")
    eng_kern = ServeEngine(cfg, None, sampler="topp_kernel")
    logits = jnp.asarray(
        np.random.default_rng(5).standard_normal((2, cfg.vocab_size)) * 3,
        jnp.float32)
    a = eng_scan._sample(logits, key)
    b = eng_kern._sample(logits, key)
    assert a.shape == b.shape == (2,)
    assert np.all(np.asarray(b) >= 0) and np.all(np.asarray(b) < cfg.vocab_size)
