import os
import sys

# Tests run on the single real CPU device (the 512-device override is ONLY for
# the dry-run). Subprocess-based distributed tests set XLA_FLAGS themselves.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
