import os
import sys

# Tests run on the single real CPU device (the 512-device override is ONLY for
# the dry-run). Subprocess-based distributed tests set XLA_FLAGS themselves.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Hypothesis profiles for the precision sweeps (tests/test_precision.py).
# CI runs the "ci" profile (derandomized: a red CI run reproduces locally from
# the printed seed-free example); nightly passes --hypothesis-seed=random via
# HYPOTHESIS_PROFILE=nightly for fresh adversarial examples every night.
# Gated: the container may not ship hypothesis (the sweeps then fall back to
# the seeded deterministic parametrizations, which always run).
try:
    from hypothesis import HealthCheck, settings

    settings.register_profile(
        "ci", derandomize=True, max_examples=20, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile(
        "nightly", max_examples=150, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("dev", max_examples=30, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:
    pass
