"""Degenerate sampler inputs across every method + the segmented path (ISSUE 8).

``temperature -> 0`` (greedy limit), ``p in {0, 1}``, single-token vocab,
all-``-inf`` rows and NaN logits, for all four ``method=`` values and
``segment_top_p_sample`` — hypothesis-driven where available (gated like
``test_operator_edges.py``), deterministic parametrizations otherwise.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # property tests skip (not error) in minimal environments
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import guards
from repro.core.primitives import top_p_sample
from repro.core.segmented import segment_top_p_sample

S = 8
METHODS_ALL = ["vector", "matmul", "kernel", "blocked"]
LOGITS = jnp.asarray([[0.0, 3.0, 1.0, -2.0], [5.0, 0.0, 0.0, 0.0]])
U = jnp.asarray([[0.4], [0.7]])


def _seg(logits):
    """Pack a rectangular (B, V) logits batch as segments of one array."""
    b, v = logits.shape
    return logits.reshape(b * v), jnp.arange(b + 1, dtype=jnp.int32) * v


# ---------------------------------------------------------------------------
# temperature -> 0: the deterministic greedy limit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS_ALL)
def test_temperature_zero_is_argmax(method):
    tok = top_p_sample(LOGITS, None, temperature=0.0, method=method,
                       tile_s=S, u=U)
    assert tok.tolist() == [1, 0]


def test_temperature_zero_segmented_matches_batched():
    vals, off = _seg(LOGITS)
    tok = segment_top_p_sample(vals, off, None, temperature=0.0, u=U)
    assert tok.tolist() == [1, 0]


@pytest.mark.parametrize("method", ["vector", "matmul"])
def test_small_temperature_converges_to_greedy(method):
    tok = top_p_sample(LOGITS, None, temperature=1e-4, method=method,
                       tile_s=S, u=U)
    assert tok.tolist() == [1, 0]


def test_temperature_zero_breaks_ties_to_lowest_id():
    tied = jnp.asarray([[2.0, 7.0, 7.0, 1.0]])
    tok = top_p_sample(tied, None, temperature=0.0)
    assert tok.tolist() == [1]
    vals, off = _seg(tied)
    stok = segment_top_p_sample(vals, off, None, temperature=0.0)
    assert stok.tolist() == [1]


# ---------------------------------------------------------------------------
# p in {0, 1}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS_ALL)
def test_p_zero_keeps_only_top_token(method):
    tok = top_p_sample(LOGITS, None, p=0.0, method=method, tile_s=S, u=U)
    assert tok.tolist() == [1, 0]


@pytest.mark.parametrize("method", METHODS_ALL)
def test_p_one_samples_full_distribution(method):
    tok = top_p_sample(LOGITS, None, p=1.0, method=method, tile_s=S, u=U)
    assert tok.shape == (2,)
    assert bool(jnp.all((tok >= 0) & (tok < LOGITS.shape[-1])))


def test_p_extremes_segmented():
    vals, off = _seg(LOGITS)
    assert segment_top_p_sample(vals, off, None, p=0.0,
                                u=U).tolist() == [1, 0]
    tok = segment_top_p_sample(vals, off, None, p=1.0, u=U)
    assert bool(jnp.all((tok >= 0) & (tok < LOGITS.shape[-1])))


# ---------------------------------------------------------------------------
# single-token vocab
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS_ALL)
def test_single_token_vocab(method):
    one = jnp.asarray([[3.5], [0.0]])
    tok = top_p_sample(one, None, method=method, tile_s=S,
                       u=jnp.asarray([[0.1], [0.9]]))
    assert tok.tolist() == [0, 0]


def test_single_token_segments():
    vals = jnp.asarray([3.5, 0.0])
    off = jnp.asarray([0, 1, 2])
    tok = segment_top_p_sample(vals, off, None,
                               u=jnp.asarray([[0.1], [0.9]]))
    assert tok.tolist() == [0, 0]


# ---------------------------------------------------------------------------
# all--inf rows (fully masked) and NaN logits
# ---------------------------------------------------------------------------


MASKED = jnp.asarray([[-jnp.inf, -jnp.inf, -jnp.inf], [0.0, 2.0, 1.0]])
POISONED = jnp.asarray([[0.0, jnp.nan, 4.0], [0.0, 2.0, 1.0]])
U3 = jnp.asarray([[0.5], [0.5]])


@pytest.mark.parametrize("method", ["vector", "matmul"])
def test_all_inf_row_sanitize_greedy_fallback(method):
    tok = top_p_sample(MASKED, None, method=method, tile_s=S, u=U3,
                       nonfinite="sanitize")
    assert tok[0] == 0            # fully-masked row -> deterministic index 0
    assert tok[1] == 1            # healthy row samples normally


def test_all_inf_row_raise_rejected_but_partial_mask_legal():
    with pytest.raises(guards.NonFiniteError):
        top_p_sample(MASKED, None, u=U3, nonfinite="raise")
    part = jnp.asarray([[-jnp.inf, 2.0, 1.0]])
    tok = top_p_sample(part, None, u=jnp.asarray([[0.5]]), nonfinite="raise")
    assert int(tok[0]) != 0       # the masked token is never sampled


def test_nan_logits_policies():
    with guards.checks(False):
        tok = top_p_sample(POISONED, None, u=U3)     # propagate: no crash
    assert tok.shape == (2,)
    with pytest.raises(guards.NonFiniteError):
        top_p_sample(POISONED, None, u=U3, nonfinite="raise")
    tok = top_p_sample(POISONED, None, u=U3, nonfinite="sanitize")
    assert tok[0] == 2            # greedy over finite entries of the bad row
    assert bool(jnp.all((tok >= 0) & (tok < 3)))


def test_segmented_masked_and_nan_policies():
    vals, off = _seg(MASKED)
    tok = segment_top_p_sample(vals, off, None, u=U3, nonfinite="sanitize")
    assert tok[0] == 0
    with pytest.raises(guards.NonFiniteError):
        segment_top_p_sample(vals, off, None, u=U3, nonfinite="raise")
    pvals, poff = _seg(POISONED)
    with pytest.raises(guards.NonFiniteError):
        segment_top_p_sample(pvals, poff, None, u=U3, nonfinite="raise")
    tok = segment_top_p_sample(pvals, poff, None, u=U3, nonfinite="sanitize")
    assert tok[0] == 2


# ---------------------------------------------------------------------------
# property-based (hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(st.integers(0, 2**31 - 1), st.floats(0.0, 1.0),
           st.integers(2, 16))
    @settings(max_examples=20, deadline=None)
    def test_sampled_token_always_in_range(seed, p, v):
        rng = np.random.default_rng(seed)
        logits = jnp.asarray(rng.normal(size=(2, v)), jnp.float32)
        u = jnp.asarray(rng.uniform(size=(2, 1)), jnp.float32)
        tok = top_p_sample(logits, None, p=p, u=u, method="vector")
        assert bool(jnp.all((tok >= 0) & (tok < v)))
        vals, off = _seg(logits)
        stok = segment_top_p_sample(vals, off, None, p=p, u=u)
        assert bool(jnp.all((stok >= 0) & (stok < v)))

    @given(st.integers(0, 2**31 - 1), st.integers(2, 12))
    @settings(max_examples=20, deadline=None)
    def test_greedy_limit_matches_argmax_property(seed, v):
        rng = np.random.default_rng(seed)
        logits = jnp.asarray(rng.normal(size=(3, v)), jnp.float32)
        tok = top_p_sample(logits, None, temperature=0.0)
        np.testing.assert_array_equal(
            np.asarray(tok), np.argmax(np.asarray(logits), axis=-1))

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed — property tests skipped")
    def test_property_based_placeholder():
        pass  # visible placeholder so missing hypothesis shows as a skip
