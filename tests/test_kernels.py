"""Pallas kernels vs pure-jnp oracles (interpret=True shape/dtype sweeps)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import scan_kernel, ssd_kernel
from repro.kernels.ref import scan_ref, ssd_ref


@pytest.mark.parametrize("s", [8, 32, 128])
@pytest.mark.parametrize("n", [64, 777, 5000])
@pytest.mark.parametrize("dtype", ["float32", "int8", "int32", "bfloat16"])
def test_scan_kernel_sweep(s, n, dtype):
    rng = np.random.default_rng(s * n)
    if dtype in ("int8", "int32"):
        hi = 3 if dtype == "int8" else 100
        x = jnp.asarray(rng.integers(-hi, hi + 1, n), dtype)
    else:
        x = jnp.asarray(rng.standard_normal(n), dtype)
    out = scan_kernel(x, s=s)
    ref = scan_ref(x)
    assert out.dtype == ref.dtype
    tol = 2e-1 if dtype == "bfloat16" else 1e-3
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               np.asarray(ref, np.float64), rtol=tol, atol=tol)


@pytest.mark.parametrize("variant", ["scanu", "scanul1"])
def test_scan_kernel_variants_batched(variant):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((3, 700)), jnp.float32)
    out = scan_kernel(x, s=16, variant=variant)
    np.testing.assert_allclose(np.asarray(out), np.cumsum(np.asarray(x), -1),
                               rtol=1e-4, atol=1e-3)


def test_scan_kernel_carry_across_many_tiles():
    """The SMEM 'partial' must thread through a long grid."""
    x = jnp.ones((2, 8 * 8 * 40), jnp.float32)
    out = scan_kernel(x, s=8)
    np.testing.assert_allclose(np.asarray(out)[:, -1], 8 * 8 * 40)


@pytest.mark.parametrize("shape", [(1, 64, 1, 4, 2), (2, 96, 3, 8, 4),
                                   (1, 250, 2, 16, 8)])
def test_ssd_kernel_sweep(shape):
    b, s, h, p, n = shape
    rng = np.random.default_rng(s)
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal((b, s, h)) * 0.1), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32)
    out = ssd_kernel(x, a, bm, cm, chunk=32)
    ref = ssd_ref(x, a, bm, cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_kernel_route_via_core_api():
    from repro.core import scan
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(999), jnp.float32)
    out = scan(x, method="kernel", tile_s=16)
    np.testing.assert_allclose(np.asarray(out), np.cumsum(np.asarray(x)),
                               rtol=1e-4, atol=1e-3)
