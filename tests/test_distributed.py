"""Distributed behaviour on 8 host devices (subprocess: device count is locked at
jax init, so each test spawns a fresh interpreter with XLA_FLAGS set)."""
import os
import subprocess
import sys
import textwrap


SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(code: str, timeout=520):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_mcscan_multi_device():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mcscan
        from repro.utils.compat import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(1)
        x = rng.standard_normal((2, 4096)).astype(np.float32)
        out = mcscan(jnp.asarray(x), mesh, "data", batch_axis_name="model")
        np.testing.assert_allclose(np.asarray(out), np.cumsum(x, -1),
                                   rtol=1e-4, atol=1e-3)
        m = (rng.random((1, 8192)) < 0.5).astype(np.int8)
        om = mcscan(jnp.asarray(m), mesh, "data")
        assert om.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(om),
                                      np.cumsum(m.astype(np.int32), -1))
        # the distributed scan must move exactly ONE small all-gather
        f = jax.jit(lambda a: mcscan(a, mesh, "data"))
        txt = f.lower(jnp.asarray(x)).compile().as_text()
        ag = [l for l in txt.splitlines() if "= " in l and "all-gather(" in l]
        assert len(ag) == 1, ag
        print("MCSCAN-8DEV-OK")
        """)


def test_data_parallel_training_step():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.models.model import get_config
        from repro.training.trainer import Trainer
        from repro.training.optimizer import AdamWConfig
        from repro.data.pipeline import SyntheticLM
        cfg = get_config("qwen3-4b", smoke=True)
        mesh = make_debug_mesh()                       # (4 data, 2 model)
        tr = Trainer(cfg, AdamWConfig(lr=1e-3), mesh=mesh)
        src = SyntheticLM(cfg.vocab_size, 32, 8)
        state = tr.init_state(jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
        l0 = None
        for i in range(3):
            state, m = tr.train_step(state, batch)
            l0 = l0 or float(m["loss"])
        assert float(m["loss"]) < l0
        # single-device run must produce the same first-step loss
        tr1 = Trainer(cfg, AdamWConfig(lr=1e-3))
        s1 = tr1.init_state(jax.random.PRNGKey(0))
        _, m1 = tr1.train_step(s1, batch)
        print("LOSSES", float(m1["loss"]), l0)
        np.testing.assert_allclose(float(m1["loss"]), l0, rtol=1e-3)
        print("DP-TRAIN-OK")
        """)


def test_checkpoint_reshard_elastic():
    run_sub("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.checkpoint import CheckpointManager
        from repro.utils.compat import make_mesh
        mesh8 = make_mesh((8,), ("data",))
        mesh2 = make_mesh((2, 2), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        tree = {"w": jax.device_put(x, NamedSharding(mesh8, P("data", None)))}
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, async_save=False)
            cm.save(1, tree, blocking=True)
            # elastic restart: restore on a DIFFERENT mesh layout
            shards = {"w": NamedSharding(mesh2, P("model", "data"))}
            out = cm.restore(1, tree, shardings=shards)
            np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))
            assert out["w"].sharding.spec == P("model", "data")
        print("RESHARD-OK")
        """)


def test_compressed_gradient_allreduce():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.training.grad_compression import (compressed_psum,
                                                     quantize_int8,
                                                     dequantize_int8)
        from repro.utils.compat import make_mesh, shard_map
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = rng.standard_normal((8, 64)).astype(np.float32)
        def body(gl, el):
            return compressed_psum(gl, "data", el)
        out, err = shard_map(body, mesh=mesh,
                                 in_specs=(P("data", None), P("data", None)),
                                 out_specs=(P(), P("data", None)))(
            jnp.asarray(g), jnp.zeros_like(jnp.asarray(g)))
        out = np.asarray(out)[0]
        ref = g.mean(0)
        # int8 quantisation: within ~1% of the fp32 mean gradient
        err_rel = np.abs(np.asarray(out) - ref).max() / np.abs(ref).max()
        assert err_rel < 0.05, err_rel
        # error feedback: quant error is retained locally, not lost
        q, s = quantize_int8(jnp.asarray(g[0]))
        np.testing.assert_allclose(
            np.asarray(dequantize_int8(q, s) + (jnp.asarray(g[0]) - dequantize_int8(q, s))),
            g[0], rtol=1e-6)
        print("COMPRESS-OK")
        """)


def test_moe_ep_shard_map_matches_local():
    """The explicit expert-parallel shard_map MoE (EXPERIMENTS §Perf I9) must be
    numerically identical to the meshless local dispatch."""
    run_sub("""
        import numpy as np, jax
        from repro.launch.mesh import make_debug_mesh
        from repro.models.model import get_config, build_model, synth_batch
        from repro.configs.base import SMOKE_SHAPE
        from repro.utils.sharding import use_mesh
        cfg = get_config("deepseek-moe-16b", smoke=True)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = synth_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
        ref = np.asarray(m.forward(params, batch), np.float32)
        mesh = make_debug_mesh()
        with use_mesh(mesh):
            out = np.asarray(jax.jit(m.forward)(params, batch), np.float32)
        err = np.abs(out - ref).max()
        assert err < 2e-2, err
        print("EP-MATCH-OK")
        """)


def test_dryrun_debug_mesh_cells():
    out = run_sub("""
        import sys
        sys.argv = ["dryrun", "--arch", "gemma2-2b", "--shape", "decode_32k",
                    "--mesh", "both", "--debug-mesh"]
        import runpy
        try:
            runpy.run_module("repro.launch.dryrun", run_name="__main__")
        except SystemExit as e:
            assert e.code == 0, "dryrun failed"
        print("DRYRUN-DEBUG-OK")
        """, timeout=560)
    assert "DRYRUN-DEBUG-OK" in out
