"""Per-architecture smoke tests (reduced configs): one forward + train step on CPU,
output shapes + no NaNs; prefill/decode consistency vs teacher forcing."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SMOKE_SHAPE
from repro.models.model import ARCHS, build_model, get_config, synth_batch

ALL = list(ARCHS)


@pytest.mark.parametrize("arch", ALL)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch = synth_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(2))
    logits = m.forward(params, batch)
    s_total = batch["tokens"].shape[1] + (cfg.n_img_tokens
                                          if cfg.family == "vlm" else 0)
    assert logits.shape == (SMOKE_SHAPE.global_batch, s_total, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    (loss, metrics), grads = jax.value_and_grad(m.loss, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss))
    gnorm = np.sqrt(sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                        for g in jax.tree.leaves(grads)))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ALL)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch, smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch = synth_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(2))
    s = batch["tokens"].shape[1]
    off = cfg.n_img_tokens if cfg.family == "vlm" else 0
    cache_len = off + s + 2
    full = np.asarray(m.forward(params, batch), np.float32)
    s0 = s - 2
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s0]
    last, caches = m.prefill(params, pre, cache_len=cache_len)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               full[:, off + s0 - 1], rtol=2e-3, atol=2e-3)
    for t in range(2):
        tok = batch["tokens"][:, s0 + t][:, None]
        logits, caches = m.decode_step(params, tok, caches,
                                       jnp.asarray(off + s0 + t, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   full[:, off + s0 + t], rtol=5e-3, atol=5e-3)


def test_unrolled_matches_scanned_layers():
    """scan-over-layers and unrolled layers are the same computation."""
    import dataclasses
    cfg = get_config("qwen3-4b", smoke=True)
    m1 = build_model(cfg)
    m2 = build_model(dataclasses.replace(cfg, scan_layers=False))
    params = m1.init(jax.random.PRNGKey(0))
    batch = synth_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
    a = np.asarray(m1.forward(params, batch), np.float32)
    b = np.asarray(m2.forward(params, batch), np.float32)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_scan_method_toggle_matches_vector_baseline():
    """The paper's matmul scan inside MoE dispatch == the vector baseline."""
    import dataclasses
    cfg = get_config("deepseek-moe-16b", smoke=True)
    m1 = build_model(cfg)                                 # matmul scan
    m2 = build_model(dataclasses.replace(cfg, scan_method="vector"))
    params = m1.init(jax.random.PRNGKey(0))
    batch = synth_batch(cfg, SMOKE_SHAPE, jax.random.PRNGKey(1))
    a = np.asarray(m1.forward(params, batch), np.float32)
    b = np.asarray(m2.forward(params, batch), np.float32)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_local_window_masks_gemma2():
    """gemma2 local layers must not attend beyond the window."""
    cfg = get_config("gemma2-2b", smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 200, (1, 48)), jnp.int32)
    base = np.asarray(m.forward(params, {"tokens": toks}), np.float32)
    # perturbing a token beyond every window+global reach changes logits;
    # sanity: outputs differ when early token changes (global layers attend)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 7) % 200)
    pert = np.asarray(m.forward(params, {"tokens": toks2}), np.float32)
    assert not np.allclose(base[0, -1], pert[0, -1])
