"""Linear-recurrence scan (``linear_scan``): the four-method parity contract.

Bit-identity strategy (the linrec extension of the pipeline tests' rule):
multipliers drawn from {-1, 0, 1} keep every cumulative product in {-1, 0, 1}
and every windowed-product quotient exact, so all partial results of every
method — affine-pair ``associative_scan``, weighted-triangular ``W @ b``
contractions, the fused tile kernel, the blocked pipeline — are exactly
representable integers and must agree to the bit.  Gated fp32/bf16
recurrences (``a = exp(-|g|)``) are additionally checked against a sequential
``lax.scan`` oracle and cross-method to tight tolerance.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # property tests skip (not error) in minimal environments
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import cummax, cumprod, linear_scan
from repro.core.linrec import linrec_accum_dtype_for
from repro.core.segmented import segment_linear_scan

METHODS = ("vector", "matmul", "kernel", "blocked")
KW = dict(tile_s=8, block_tiles=2)
# Ragged on purpose: sub-tile, off-by-one from tile/block multiples, primes.
LENGTHS = (1, 2, 7, 63, 64, 65, 257, 1000)


def _int_pair(n, seed=0, lo=-3, hi=4):
    """Integer-valued (a, b) with a in {-1, 0, 1} — exact under any method."""
    rng = np.random.default_rng(seed)
    a = rng.integers(-1, 2, n).astype(np.float32)
    b = rng.integers(lo, hi, n).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(b)


def _gated_pair(n, seed=0, dtype=jnp.float32):
    """Gated-recurrence payload: a = exp(-|g|) in (0, 1], b ~ N(0, 1)."""
    rng = np.random.default_rng(seed)
    a = np.exp(-np.abs(rng.standard_normal(n)) * 0.1).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    return jnp.asarray(a, dtype), jnp.asarray(b, dtype)


def _seq_ref(a, b, init=0.0):
    """Sequential lax.scan oracle in fp32."""
    def step(y, t):
        at, bt = t
        y = at * y + bt
        return y, y
    _, ys = jax.lax.scan(
        step, jnp.asarray(init, jnp.float32),
        (jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)))
    return np.asarray(ys)


# ---------------------------------------------------------------------------
# bit-parity on integer-valued payloads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS[1:])
@pytest.mark.parametrize("n", LENGTHS)
def test_bit_identical_to_vector_int_payload(method, n):
    a, b = _int_pair(n, seed=n)
    ref = linear_scan(a, b, method="vector", **KW)
    got = linear_scan(a, b, method=method, **KW)
    assert got.dtype == ref.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("method", METHODS)
def test_matches_sequential_oracle_int(method):
    a, b = _int_pair(321, seed=5)
    got = linear_scan(a, b, method=method, **KW)
    np.testing.assert_array_equal(np.asarray(got), _seq_ref(a, b))


@pytest.mark.parametrize("method", METHODS[1:])
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32, jnp.bool_])
def test_integer_dtypes_accumulate_fp32(method, dtype):
    """Integer/bool inputs accumulate in fp32 (documented linrec dtype rule)."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(0, 2, 100), dtype)
    b = jnp.asarray(rng.integers(0, 2, 100), dtype)
    ref = linear_scan(a, b, method="vector", **KW)
    got = linear_scan(a, b, method=method, **KW)
    assert got.dtype == jnp.float32 == linrec_accum_dtype_for(dtype)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("method", METHODS[1:])
def test_exclusive_reverse_axis_initial_parity(method):
    a, b = _int_pair(130, seed=9)
    a2 = a.reshape(2, 65)
    b2 = b.reshape(2, 65)
    for kw in (dict(exclusive=True), dict(reverse=True),
               dict(exclusive=True, reverse=True), dict(initial=5.0),
               dict(initial=-2.0, exclusive=True), dict(axis=0)):
        ref = linear_scan(a2, b2, method="vector", **KW, **kw)
        got = linear_scan(a2, b2, method=method, **KW, **kw)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref)), kw


def test_exclusive_initial_semantics():
    a = jnp.asarray([2.0, 2.0, 2.0])
    b = jnp.asarray([1.0, 1.0, 1.0])
    out = linear_scan(a, b, exclusive=True, initial=3.0, **KW)
    # state entering each step: [init, y_0, y_1] with y_0 = 2*3 + 1 = 7
    assert out.tolist() == [3.0, 7.0, 15.0]


def test_zeros_in_a_reset_exactly():
    """True zeros of ``a`` cut every window — the masked-W edge case."""
    a = jnp.asarray([2.0, 0.0, 2.0, 2.0, 0.0, 1.0])
    b = jnp.asarray([1.0, 3.0, 1.0, 1.0, 4.0, 1.0])
    want = _seq_ref(a, b)
    for m in METHODS:
        got = linear_scan(a, b, method=m, tile_s=2, block_tiles=1)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_a_ones_recovers_cumsum():
    _, b = _int_pair(200, seed=11)
    got = linear_scan(jnp.ones_like(b), b, method="matmul", **KW)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.cumsum(np.asarray(b)).astype(np.float32))


def test_unknown_method_raises():
    a, b = _int_pair(4)
    with pytest.raises(ValueError, match="unknown scan method"):
        linear_scan(a, b, method="nope")
    with pytest.raises(ValueError, match="unknown scan method"):
        cummax(a, method="nope")
    with pytest.raises(ValueError, match="tile_s"):
        linear_scan(a, b, tile_s=512)
    with pytest.raises(TypeError):  # no silent kwarg swallowing
        cummax(a, exclusive=True)


def test_exclusive_with_array_initial():
    """Array initial (leading-dims shaped) works with exclusive=True."""
    a = jnp.ones((2, 3, 4))
    b = jnp.ones((2, 3, 4))
    init = jnp.asarray([[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]])
    out = linear_scan(a, b, exclusive=True, initial=init, method="matmul", **KW)
    np.testing.assert_array_equal(np.asarray(out[..., 0]), np.asarray(init))
    ref = linear_scan(a, b, exclusive=True, initial=init, method="vector", **KW)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("method", METHODS)
def test_shared_decay_broadcast_parity(method):
    """Decay shared over payload dims (the SSD cross-chunk shape).

    ``a`` stays unbroadcast through the matmul path — one weighted triangle
    serves the whole payload batch — and every method still matches looping
    the fully-broadcast scan.
    """
    rng = np.random.default_rng(17)
    a = jnp.asarray(rng.integers(-1, 2, (2, 33, 1, 1)).astype(np.float32))
    b = jnp.asarray(rng.integers(-2, 3, (2, 33, 3, 4)).astype(np.float32))
    got = linear_scan(a, b, axis=1, method=method, **KW)
    ref = linear_scan(jnp.broadcast_to(a, b.shape), b, axis=1,
                      method="vector", **KW)
    assert got.shape == b.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_shared_decay_matmul_builds_one_triangle():
    """The matmul path must NOT materialize a per-payload-element triangle."""
    a = jnp.ones((1, 64, 1, 1))          # decay shared across the (8, 8) payload
    b = jnp.ones((1, 64, 8, 8))
    jaxpr = jax.make_jaxpr(
        lambda a, b: linear_scan(a, b, axis=1, method="matmul", tile_s=16))(a, b)
    biggest = max((int(np.prod(v.aval.shape))
                   for eqn in jaxpr.eqns for v in eqn.outvars), default=0)
    # W for shared a is (1,1,1,nc,q,q) = 4*16*16; a per-element W would be
    # 64x larger than the payload (1*64*8*8*16... ) — cap well below that.
    assert biggest <= 4 * int(np.prod(b.shape)), biggest


@pytest.mark.parametrize("method", METHODS)
def test_shared_decay_gradients(method):
    """Adjoint sum-reduces the shared-decay cotangent back to its shape."""
    rng = np.random.default_rng(18)
    a = jnp.asarray(np.exp(-np.abs(rng.standard_normal((5, 1)))), jnp.float32)
    b = jnp.asarray(rng.standard_normal((5, 3)), jnp.float32)
    ga, gb = jax.grad(
        lambda a, b: jnp.sum(linear_scan(a, b, axis=0, method=method, **KW) ** 2),
        argnums=(0, 1))(a, b)
    assert ga.shape == a.shape and gb.shape == b.shape
    va, vb = jax.grad(
        lambda a, b: jnp.sum(linear_scan(
            jnp.broadcast_to(a, b.shape), b, axis=0, method="vector", **KW) ** 2),
        argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(va.sum(1, keepdims=True)),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(vb), rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("method", METHODS)
def test_length_one_short_circuits_without_launch(method):
    """n == 1 is the decode step: exact FMA, no kernel launch, any method."""
    a = jnp.asarray([[0.5], [2.0]])
    b = jnp.asarray([[1.0], [3.0]])
    out = linear_scan(a, b, method=method, initial=jnp.asarray([4.0, -1.0]))
    np.testing.assert_array_equal(np.asarray(out), [[3.0], [1.0]])
    launches = _count_pallas_launches(
        lambda a, b: linear_scan(a, b, method=method,
                                 initial=jnp.asarray([4.0, -1.0])),
        "linrec", a, b)
    assert launches == 0


def test_broadcasting_and_empty():
    out = linear_scan(jnp.asarray(0.5), jnp.ones((2, 5)), method="matmul", **KW)
    assert out.shape == (2, 5)
    np.testing.assert_allclose(np.asarray(out)[1],
                               2.0 - 0.5 ** np.arange(5), rtol=1e-6)
    z = linear_scan(jnp.ones((3, 0)), jnp.ones((3, 0)), method="kernel", **KW)
    assert z.shape == (3, 0)


# ---------------------------------------------------------------------------
# gated recurrences: fp32/bf16 tolerance contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("n", (63, 257, 1000))
def test_gated_fp32_close_to_sequential(method, n):
    a, b = _gated_pair(n, seed=n)
    got = np.asarray(linear_scan(a, b, method=method, **KW))
    np.testing.assert_allclose(got, _seq_ref(a, b), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("method", METHODS[1:])
def test_gated_bf16_accumulates_fp32(method):
    a, b = _gated_pair(500, seed=1, dtype=jnp.bfloat16)
    ref = linear_scan(a, b, method="vector", **KW)
    got = linear_scan(a, b, method=method, **KW)
    assert got.dtype == jnp.float32 == ref.dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("method", METHODS)
def test_deep_decay_underflow_is_finite(method):
    """Cumulative products that underflow flush to 0 — never NaN."""
    a = jnp.full((4096,), 0.5, jnp.float32)
    b = jnp.ones((4096,), jnp.float32)
    got = np.asarray(linear_scan(a, b, method=method, tile_s=64))
    assert np.all(np.isfinite(got))
    np.testing.assert_allclose(got, 2.0 - 0.5 ** np.arange(4096), rtol=1e-5)


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("decay", (0.25, 0.05))
def test_moderate_decay_full_tile_stays_accurate(method, decay):
    """Constant moderate decay over a full default tile (the regression case).

    ``0.25**k`` underflows fp32 inside one 128-element tile; the exponent-
    normalized ``W`` must keep every *short* window exact rather than
    flushing all windows anchored past the underflow point.
    """
    n = 512
    a = jnp.full((n,), decay, jnp.float32)
    b = jnp.asarray(np.random.default_rng(31).standard_normal(n), jnp.float32)
    got = np.asarray(linear_scan(a, b, method=method, tile_s=128))
    want = _seq_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=3e-6, atol=3e-6)


def test_moderate_decay_long_ssd_sequence():
    """The reviewer scenario: ssd_scan long-sequence moderate decay, every method."""
    from repro.core.ssd import ssd_scan, ssd_scan_ref
    rng = np.random.default_rng(32)
    b_, s_ = 1, 2048
    x = jnp.asarray(rng.standard_normal((b_, s_, 2, 4)), jnp.float32)
    al = jnp.full((b_, s_, 2), np.log(0.95), jnp.float32)   # ~0.2 per 32-chunk
    bm = jnp.asarray(rng.standard_normal((b_, s_, 2, 3)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b_, s_, 2, 3)) * 0.3, jnp.float32)
    ref = np.asarray(ssd_scan_ref(x, al, bm, cm))
    for method in METHODS:
        got = np.asarray(ssd_scan(x, al, bm, cm, chunk=32, scan_method=method))
        np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3,
                                   err_msg=method)


@pytest.mark.parametrize("method", METHODS)
def test_gradients_match_analytic_adjoint(method):
    a, b = _gated_pair(200, seed=7)
    a = a.at[3].set(0.0)           # exact reset inside the window
    ga, gb = jax.grad(
        lambda a, b: jnp.sum(linear_scan(a, b, method=method, **KW) ** 2),
        argnums=(0, 1))(a, b)
    va, vb = jax.grad(
        lambda a, b: jnp.sum(linear_scan(a, b, method="vector", **KW) ** 2),
        argnums=(0, 1))(a, b)
    assert np.all(np.isfinite(np.asarray(ga)))
    np.testing.assert_allclose(np.asarray(ga), np.asarray(va),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(vb),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# convenience wrappers: cumprod / cummax
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS)
def test_cumprod_parity(method):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.choice([-1.0, 0.0, 1.0, 2.0], 80).astype(np.float32))
    got = cumprod(x, method=method, **KW)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.cumprod(np.asarray(x)).astype(np.float32))


@pytest.mark.parametrize("method", METHODS[1:])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.int8, jnp.float32])
def test_cummax_bit_identical(method, dtype):
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.integers(-100, 100, 313), dtype)
    ref = cummax(x, method="vector")
    got = cummax(x, method=method, tile_s=8)
    assert got.dtype == x.dtype == ref.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("method", METHODS)
def test_cummax_bool_prefix_any(method):
    """Bool cummax == prefix-any, still bool, for every method."""
    x = jnp.asarray([False, False, True, False, True])
    out = cummax(x, method=method, tile_s=2)
    assert out.dtype == jnp.bool_
    assert out.tolist() == [False, False, True, True, True]


def test_cummax_reverse_axis():
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.integers(-9, 9, (3, 40)), jnp.int32)
    got = cummax(x, axis=0, reverse=True, method="matmul", tile_s=8)
    want = jnp.flip(jax.lax.cummax(jnp.flip(x, 0), axis=0), 0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# segment_linear_scan: boundary resets on the packed layout
# ---------------------------------------------------------------------------


def _loop_linrec(a, b, offsets, init=0.0, **kw):
    """Oracle: run 1-D linear_scan(method="vector") per segment slice."""
    out = np.zeros(a.shape[-1], np.float32)
    for i in range(len(offsets) - 1):
        lo, hi = int(offsets[i]), int(offsets[i + 1])
        if hi > lo:
            out[lo:hi] = np.asarray(linear_scan(
                a[lo:hi], b[lo:hi], method="vector", initial=init, **kw))
    return out


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("offsets", [
    [0, 57],                                # one segment == unsegmented
    [0, 0, 5, 5, 20, 21, 57],               # empties + len-1 + ragged
    [0, 1, 2, 3, 57],                       # tiny leading segments
])
def test_segment_linear_scan_matches_loop(method, offsets):
    rng = np.random.default_rng(13)
    a = jnp.asarray(rng.integers(-1, 2, 57).astype(np.float32))
    b = jnp.asarray(rng.integers(-3, 4, 57).astype(np.float32))
    off = jnp.asarray(offsets, jnp.int32)
    for init in (0.0, 2.0):
        got = segment_linear_scan(a, b, off, method=method, initial=init, **KW)
        np.testing.assert_array_equal(
            np.asarray(got), _loop_linrec(a, b, offsets, init))


@pytest.mark.parametrize("method", ("vector", "matmul"))
def test_segment_linear_scan_exclusive_reverse(method):
    rng = np.random.default_rng(14)
    a = jnp.asarray(rng.integers(-1, 2, 31).astype(np.float32))
    b = jnp.asarray(rng.integers(-2, 3, 31).astype(np.float32))
    offsets = [0, 4, 4, 17, 31]
    off = jnp.asarray(offsets, jnp.int32)
    ex = segment_linear_scan(a, b, off, method=method, exclusive=True,
                             initial=3.0, **KW)
    # segment starts carry the initial state; others the shifted inclusive
    inc = segment_linear_scan(a, b, off, method=method, initial=3.0, **KW)
    want = np.asarray(inc)
    want = np.concatenate([[0.0], want[:-1]])
    for s in offsets[:-1]:
        if s < 31:
            want[s] = 3.0
    np.testing.assert_array_equal(np.asarray(ex), want)
    rev = segment_linear_scan(a, b, off, method=method, reverse=True, **KW)
    # reverse == flipping each segment, scanning, flipping back
    want_r = np.zeros(31, np.float32)
    for i in range(len(offsets) - 1):
        lo, hi = offsets[i], offsets[i + 1]
        if hi > lo:
            want_r[lo:hi] = np.asarray(linear_scan(
                jnp.flip(a[lo:hi]), jnp.flip(b[lo:hi]),
                method="vector"))[::-1]
    np.testing.assert_array_equal(np.asarray(rev), want_r)


@pytest.mark.parametrize("method", ("vector", "matmul"))
def test_segment_linear_scan_array_initial_per_row(method):
    """A (batch,)-shaped initial applies per batch row, not per position."""
    rng = np.random.default_rng(15)
    a = jnp.asarray(rng.integers(-1, 2, (3, 10)).astype(np.float32))
    b = jnp.asarray(rng.integers(-2, 3, (3, 10)).astype(np.float32))
    offsets = [0, 4, 10]
    init = jnp.asarray([1.0, -2.0, 3.0])
    got = segment_linear_scan(a, b, jnp.asarray(offsets), method=method,
                              initial=init, **KW)
    want = np.stack([
        _loop_linrec_row(np.asarray(a[r]), np.asarray(b[r]), offsets,
                         float(init[r]))
        for r in range(3)])
    np.testing.assert_array_equal(np.asarray(got), want)
    ex = segment_linear_scan(a, b, jnp.asarray(offsets), method=method,
                             initial=init, exclusive=True, **KW)
    # every segment start carries its row's initial
    for s in offsets[:-1]:
        np.testing.assert_array_equal(np.asarray(ex[:, s]), np.asarray(init))


def _loop_linrec_row(a, b, offsets, init):
    """1-row oracle for the array-initial test."""
    out = np.zeros(a.shape[-1], np.float32)
    for i in range(len(offsets) - 1):
        lo, hi = int(offsets[i]), int(offsets[i + 1])
        y = init
        for t in range(lo, hi):
            y = a[t] * y + b[t]
            out[t] = y
    return out


def test_segment_linear_scan_empty_packed():
    out = segment_linear_scan(jnp.zeros((0,)), jnp.zeros((0,)),
                              jnp.asarray([0, 0, 0]), method="matmul")
    assert out.shape == (0,)


# ---------------------------------------------------------------------------
# launch-count guards (mirrors the segscan jaxpr guard)
# ---------------------------------------------------------------------------


def _count_pallas_launches(fn, substr, *args) -> int:
    def walk(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                nm = eqn.params.get("name_and_src_info",
                                    eqn.params.get("name", ""))
                if substr in str(nm):
                    total += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    total += walk(v.jaxpr)
                elif hasattr(v, "eqns"):
                    total += walk(v)
        return total

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def test_linrec_kernel_launch_counts():
    a, b = _gated_pair(1000, seed=21)
    got = _count_pallas_launches(
        lambda a, b: linear_scan(a, b, method="kernel", tile_s=8),
        "linrec_mm", a, b)
    assert got == 1                 # one fused sequential-grid launch

    # multi-block: summaries + affine carry scan + fused phases 1+3
    got = _count_pallas_launches(
        lambda a, b: linear_scan(a, b, method="blocked", tile_s=8,
                                 block_tiles=2),
        "linrec_pipeline", a, b)
    assert got == 3

    # single block: carry provably zero — phases 1-2 elided
    a1, b1 = _gated_pair(100, seed=22)
    got = _count_pallas_launches(
        lambda a, b: linear_scan(a, b, method="blocked", tile_s=8,
                                 block_tiles=2),
        "linrec_pipeline", a1, b1)
    assert got == 1


# ---------------------------------------------------------------------------
# property-based (hypothesis): random payloads vs the vector oracle
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.sampled_from([-1, 0, 1]), min_size=1, max_size=80),
           st.lists(st.integers(-4, 4), min_size=1, max_size=80),
           st.sampled_from(["matmul", "kernel", "blocked"]))
    def test_linear_scan_property(avals, bvals, method):
        n = min(len(avals), len(bvals))
        a = jnp.asarray(avals[:n], jnp.float32)
        b = jnp.asarray(bvals[:n], jnp.float32)
        ref = linear_scan(a, b, method="vector", **KW)
        got = linear_scan(a, b, method=method, **KW)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.sampled_from([-1, 0, 1]), min_size=1, max_size=60),
           st.lists(st.integers(0, 60), min_size=0, max_size=5),
           st.sampled_from(["matmul", "blocked"]))
    def test_segment_linear_scan_property(avals, cuts, method):
        n = len(avals)
        a = jnp.asarray(avals, jnp.float32)
        b = jnp.ones((n,), jnp.float32)
        offsets = np.concatenate(
            [[0], np.sort(np.clip(cuts, 0, n)), [n]]).astype(np.int32)
        got = segment_linear_scan(a, b, jnp.asarray(offsets), method=method,
                                  **KW)
        np.testing.assert_array_equal(np.asarray(got),
                                      _loop_linrec(a, b, offsets))

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed — property tests skipped")
    def test_linear_scan_property_placeholder():
        pass  # visible placeholder so missing hypothesis shows as a skip
