"""Multi-way SplitInd (radix-2^k) and ``bits_per_pass`` radix-sort parity.

Acceptance contract (ISSUE 3): every (method, bits_per_pass) combination is
bit-identical to ``method="vector"`` with ``bits_per_pass=1`` — bucket offsets
stay exact int8 -> int32 mask scans — across int8/int16/int32/bf16/fp16/fp32
keys, odd/ragged lengths and descending order; and the fused sort executes
exactly ``ceil(bits / bits_per_pass)`` radix-pass launches.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # property tests skip (not error) in minimal environments
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import multi_split, radix_sort, sort

S = 16                        # kernel mask-scan row width (small: interpret speed)
METHODS_ALL = ["vector", "matmul", "kernel", "blocked"]

_KEY_DTYPES = {
    "int8": jnp.int8, "int16": jnp.int16, "int32": jnp.int32,
    "bfloat16": jnp.bfloat16, "float16": jnp.float16, "float32": jnp.float32,
}
_SORT_BITS = {"int8": 8, "int16": 16, "int32": 32,
              "bfloat16": 16, "float16": 16, "float32": 32}


def _keys(dtype_name, n, seed):
    rng = np.random.default_rng(seed)
    dt = _KEY_DTYPES[dtype_name]
    if dtype_name in ("int8", "int16", "int32"):
        info = np.iinfo(dtype_name)
        return jnp.asarray(rng.integers(info.min, info.max, n), dt)
    return jnp.asarray(rng.standard_normal(n), dt)


def _as_comparable(a):
    """bf16/f16 arrays -> f32 numpy so assert_array_equal compares values."""
    if a.dtype in (jnp.bfloat16, jnp.float16):
        a = a.astype(jnp.float32)
    return np.asarray(a)


# ---------------------------------------------------------------------------
# multi_split
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS_ALL)
def test_multi_split_matches_stable_argsort(method):
    rng = np.random.default_rng(0)
    n, buckets = 77, 8
    x = rng.standard_normal(n).astype(np.float32)
    d = rng.integers(0, buckets, n)
    z, ind, counts = multi_split(jnp.asarray(x), jnp.asarray(d), buckets,
                                 method=method, tile_s=S)
    order = np.argsort(d, kind="stable")
    np.testing.assert_array_equal(np.asarray(z), x[order])
    np.testing.assert_array_equal(np.asarray(ind), order)
    np.testing.assert_array_equal(np.asarray(counts),
                                  np.bincount(d, minlength=buckets))


def test_multi_split_parity_batched_ragged():
    """Fused kernel vs vector on a batched, non-multiple-of-s² length."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((4, 333)), jnp.float32)
    d = jnp.asarray(rng.integers(0, 16, (4, 333)))
    zv, iv, cv = multi_split(x, d, 16, method="vector", tile_s=S)
    zk, ik, ck = multi_split(x, d, 16, method="kernel", tile_s=S)
    np.testing.assert_array_equal(np.asarray(zv), np.asarray(zk))
    np.testing.assert_array_equal(np.asarray(iv), np.asarray(ik))
    np.testing.assert_array_equal(np.asarray(cv), np.asarray(ck))
    assert ck.shape == (4, 16)


def test_multi_split_empty_and_full_buckets():
    """Buckets with zero elements and a bucket holding everything."""
    x = jnp.arange(5, dtype=jnp.int32)
    for digits in ([3, 3, 3, 3, 3], [0, 0, 0, 0, 0]):
        d = jnp.asarray(digits)
        for method in ("vector", "kernel"):
            z, ind, c = multi_split(x, d, 4, method=method, tile_s=S)
            np.testing.assert_array_equal(np.asarray(z), np.arange(5))
            np.testing.assert_array_equal(np.asarray(ind), np.arange(5))
            assert int(c[digits[0]]) == 5 and int(c.sum()) == 5


def test_multi_split_single_bucket_is_identity():
    x = jnp.asarray([5, 1, 7], jnp.int32)
    z, ind, c = multi_split(x, jnp.zeros(3, jnp.int32), 1, method="kernel",
                            tile_s=S)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(ind), np.arange(3))
    assert c.shape == (1,) and int(c[0]) == 3


def test_multi_split_return_indices_false_and_validation():
    x = jnp.arange(4, dtype=jnp.int32)
    d = jnp.asarray([1, 0, 1, 0])
    z, c = multi_split(x, d, 2, return_indices=False, tile_s=S)
    np.testing.assert_array_equal(np.asarray(z), [1, 3, 0, 2])
    with pytest.raises(ValueError):
        multi_split(x, d, 0)
    with pytest.raises(ValueError):
        multi_split(x, d, 2, method="cube")


# ---------------------------------------------------------------------------
# radix sort: bits_per_pass parity matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", list(_KEY_DTYPES))
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_radix_sort_bits_per_pass_parity(dtype, k):
    """vector/k vs the per-bit vector oracle and numpy, ragged length."""
    n = 131
    x = _keys(dtype, n, seed=n + k)
    vr, ir = radix_sort(x, method="vector", bits_per_pass=1, tile_s=S)
    v, i = radix_sort(x, method="vector", bits_per_pass=k, tile_s=S)
    np.testing.assert_array_equal(_as_comparable(v), _as_comparable(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_array_equal(
        _as_comparable(v), np.sort(_as_comparable(x), kind="stable"))


@pytest.mark.parametrize("method", ["matmul", "kernel", "blocked"])
@pytest.mark.parametrize("k", [2, 4, 8])
def test_radix_sort_method_parity_fp32(method, k):
    """Every (method, k) bit-identical to vector per-bit on fp32 keys."""
    x = _keys("float32", 77, seed=k)
    vr, ir = radix_sort(x, method="vector", bits_per_pass=1, tile_s=S)
    v, i = radix_sort(x, method=method, bits_per_pass=k, tile_s=S)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))


@pytest.mark.parametrize("k", [1, 4, 8])
def test_radix_sort_descending_batched_bits_per_pass(k):
    x = jnp.asarray(np.random.default_rng(2).standard_normal((2, 65)),
                    jnp.bfloat16)
    vr, ir = sort(x, descending=True, method="vector", bits_per_pass=1,
                  tile_s=S)
    vk, ik = sort(x, descending=True, method="kernel", bits_per_pass=k,
                  tile_s=S)
    np.testing.assert_array_equal(_as_comparable(vr), _as_comparable(vk))
    np.testing.assert_array_equal(np.asarray(ir), np.asarray(ik))


def test_radix_sort_rejects_bad_bits_per_pass():
    x = jnp.arange(8, dtype=jnp.int32)
    for bad in (0, 9, -1):
        with pytest.raises(ValueError):
            radix_sort(x, bits_per_pass=bad)


def test_radix_sort_bits_per_pass_wider_than_key():
    """k=8 on an 8-bit key is one pass and still exact."""
    x = _keys("int8", 200, seed=3)
    v, i = radix_sort(x, method="kernel", bits_per_pass=8, tile_s=S)
    np.testing.assert_array_equal(np.asarray(v),
                                  np.sort(np.asarray(x), kind="stable"))
    np.testing.assert_array_equal(np.asarray(x)[np.asarray(i)], np.asarray(v))


# ---------------------------------------------------------------------------
# fused pass-count guard (mirrors the bench-smoke CI assertion)
# ---------------------------------------------------------------------------


def _count_radix_pass_launches(fn, *args) -> int:
    def walk(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                nm = eqn.params.get("name_and_src_info",
                                    eqn.params.get("name", ""))
                if "radix_pass" in str(nm):
                    total += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    total += walk(v.jaxpr)
                elif hasattr(v, "eqns"):
                    total += walk(v)
        return total

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


@pytest.mark.parametrize("dtype,bits", [("float32", 32), ("bfloat16", 16)])
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_fused_sort_executes_ceil_bits_over_k_passes(dtype, bits, k):
    x = _keys(dtype, 64, seed=0)
    got = _count_radix_pass_launches(
        lambda a: radix_sort(a, method="kernel", bits_per_pass=k,
                             tile_s=S)[0], x)
    assert got == -(-bits // k)


# ---------------------------------------------------------------------------
# serving: bits_per_pass reaches the sampler
# ---------------------------------------------------------------------------


def test_serving_engine_bits_per_pass():
    from repro.models.model import get_config
    from repro.serving.engine import ServeEngine

    cfg = get_config("llama3-8b", smoke=True)
    logits = jnp.asarray(
        np.random.default_rng(7).standard_normal((2, cfg.vocab_size)) * 3,
        jnp.float32)
    key = jax.random.PRNGKey(0)
    ref = ServeEngine(cfg, None, sampler="topp_scan",
                      bits_per_pass=1)._sample(logits, key)
    for k in (4, 8):
        got = ServeEngine(cfg, None, sampler="topp_scan",
                          bits_per_pass=k)._sample(logits, key)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    with pytest.raises(ValueError):   # eager: fails at construction, not in jit
        ServeEngine(cfg, None, bits_per_pass=0)


# ---------------------------------------------------------------------------
# property-based (hypothesis): stability, counts, permutation validity
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(0, 7), min_size=1, max_size=120),
           st.sampled_from(["vector", "matmul"]))
    def test_multi_split_properties(digits, method):
        d = np.asarray(digits)
        n = d.size
        x = np.arange(n, dtype=np.int32)          # payload = original index
        z, ind, counts = multi_split(jnp.asarray(x), jnp.asarray(d), 8,
                                     method=method, tile_s=S)
        z, ind, counts = np.asarray(z), np.asarray(ind), np.asarray(counts)
        # bucket counts: exactly the digit histogram, summing to n
        np.testing.assert_array_equal(counts, np.bincount(d, minlength=8))
        assert counts.sum() == n
        # permutation validity: ind is a permutation of 0..n-1 and z == x[ind]
        np.testing.assert_array_equal(np.sort(ind), np.arange(n))
        np.testing.assert_array_equal(z, x[ind])
        # grouping + stability: digits non-decreasing, original order kept
        # within each bucket (payload == original index makes this checkable)
        np.testing.assert_array_equal(d[ind], np.sort(d, kind="stable"))
        for b in range(8):
            in_bucket = ind[d[ind] == b]
            np.testing.assert_array_equal(in_bucket, np.sort(in_bucket))

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(0, 2**16 - 1), min_size=1, max_size=120),
           st.integers(1, 8))
    def test_radix_sort_property_uint16(keys, k):
        x = np.asarray(keys, np.uint16)
        v, i = radix_sort(jnp.asarray(x), method="vector", bits_per_pass=k,
                          tile_s=S)
        np.testing.assert_array_equal(np.asarray(v), np.sort(x, kind="stable"))
        np.testing.assert_array_equal(x[np.asarray(i)], np.asarray(v))
        np.testing.assert_array_equal(np.sort(np.asarray(i)), np.arange(x.size))

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed — property tests skipped")
    def test_multi_split_properties_placeholder():
        pass  # visible placeholder so missing hypothesis shows as a skip
