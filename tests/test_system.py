"""End-to-end behaviour: train a tiny LM on structured data, serve it with the
paper's scan-based top-p sampler, and check the full pipeline learns + generates."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data.pipeline import SyntheticLM
from repro.models.model import get_config
from repro.serving.engine import ServeEngine
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer


def test_train_then_serve_end_to_end():
    cfg = get_config("llama3-8b", smoke=True)
    src = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    tr = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60))
    out = tr.fit(src, 30, log_every=0)
    assert out["losses"][-1] < out["losses"][0] - 0.5, out["losses"][::10]

    eng = ServeEngine(cfg, out["state"]["params"], max_len=96, top_p=0.9,
                      sampler="topp_scan")
    prompts = jnp.asarray(src.batch_at(777)["tokens"][:2, :32])
    toks = eng.generate({"tokens": prompts}, 8, jax.random.PRNGKey(0))
    assert toks.shape == (2, 8)
    assert np.all(np.asarray(toks) >= 0)
    assert np.all(np.asarray(toks) < cfg.vocab_size)   # padded vocab masked


def test_greedy_vs_topp_sampler_agree_when_peaked():
    """After training, the distribution is peaked; top-p(0.2) ≈ greedy."""
    cfg = get_config("llama3-8b", smoke=True)
    src = SyntheticLM(cfg.vocab_size, 64, 8, seed=0)
    tr = Trainer(cfg, AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=150))
    out = tr.fit(src, 120, log_every=0)
    prompts = jnp.asarray(src.batch_at(5)["tokens"][:2, :32])
    g = ServeEngine(cfg, out["state"]["params"], max_len=64, sampler="greedy")
    p = ServeEngine(cfg, out["state"]["params"], max_len=64, top_p=0.2,
                    sampler="topp_scan")
    tg = np.asarray(g.generate({"tokens": prompts}, 4, jax.random.PRNGKey(1)))
    tp = np.asarray(p.generate({"tokens": prompts}, 4, jax.random.PRNGKey(1)))
    assert np.mean(tg == tp) > 0.6


def test_serve_engine_scan_method_override_recurrent_decode():
    """ServeEngine(scan_method=...) picks the linear_scan path for SSM decode.

    Greedy decode of a recurrent (Mamba2) model must produce the same tokens
    whichever linear-recurrence method the stateful state updates run on —
    the decode-side face of the linrec parity contract.
    """
    from repro.models.model import build_model

    cfg = get_config("zamba2-1.2b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16)))
    ref = None
    for method in ("vector", "matmul"):
        eng = ServeEngine(cfg, params, max_len=32, sampler="greedy",
                          scan_method=method)
        assert eng.cfg.scan_method == method
        toks = np.asarray(eng.generate({"tokens": prompts}, 4,
                                       jax.random.PRNGKey(1)))
        if ref is None:
            ref = toks
        else:
            np.testing.assert_array_equal(toks, ref)


def test_serve_engine_rejects_unknown_scan_method():
    cfg = get_config("llama3-8b", smoke=True)
    try:
        ServeEngine(cfg, None, scan_method="cube")
    except ValueError as e:
        assert "scan_method" in str(e)
    else:  # pragma: no cover
        raise AssertionError("expected ValueError for unknown scan_method")


def _tiny_engine(max_len=32, **kw):
    from repro.models.model import build_model

    cfg = get_config("llama3-8b", smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_len=max_len, sampler="greedy", **kw)


def test_generate_zero_tokens_returns_empty():
    """max_new_tokens=0 must return (B, 0), not a stray prefill token."""
    eng = _tiny_engine()
    batch = {"tokens": jnp.ones((2, 4), jnp.int32)}
    out = eng.generate(batch, 0, jax.random.PRNGKey(0))
    assert out.shape == (2, 0)
    import pytest
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.generate(batch, -1, jax.random.PRNGKey(0))


def test_generate_rejects_kv_cache_overflow():
    """prompt + max_new_tokens past max_len fails eagerly, not silently."""
    import pytest

    eng = _tiny_engine(max_len=16)
    batch = {"tokens": jnp.ones((1, 8), jnp.int32)}
    assert eng.generate(batch, 8, jax.random.PRNGKey(0)).shape == (1, 8)
    with pytest.raises(ValueError, match="KV cache budget"):
        eng.generate(batch, 9, jax.random.PRNGKey(0))


def test_generate_eos_early_exit():
    """eos_id= stops decoding once every row emitted it; finished rows pad."""
    eng = _tiny_engine()
    batch = {"tokens": jnp.ones((2, 4), jnp.int32)}
    key = jax.random.PRNGKey(0)
    full = np.asarray(eng.generate(batch, 6, key))      # greedy: deterministic
    eos = int(full[0, 2])
    out = np.asarray(eng.generate(batch, 6, key, eos_id=eos))
    assert out.shape[0] == 2 and out.shape[1] <= 6
    # prefix before each row's eos matches the unrestricted decode
    for r in range(2):
        hits = np.nonzero(full[r] == eos)[0]
        stop = int(hits[0]) if hits.size else out.shape[1] - 1
        np.testing.assert_array_equal(out[r, :stop + 1],
                                      full[r, :stop + 1])
        assert np.all(out[r, stop:] == eos) or hits.size == 0


def test_generate_eos_sync_every_bit_identical():
    """The device-side done mask syncs once per tick; any tick size must
    reproduce the per-token early exit byte for byte (with and without eos)."""
    eng = _tiny_engine()
    batch = {"tokens": jnp.ones((2, 4), jnp.int32)}
    key = jax.random.PRNGKey(0)
    full = np.asarray(eng.generate(batch, 6, key))
    eos = int(full[0, 2])
    ref = np.asarray(eng.generate(batch, 6, key, eos_id=eos, sync_every=1))
    for se in (2, 3, 8, 100):
        np.testing.assert_array_equal(
            np.asarray(eng.generate(batch, 6, key, eos_id=eos,
                                    sync_every=se)), ref)
    # no eos: sync_every must be a no-op on the stream
    np.testing.assert_array_equal(
        np.asarray(eng.generate(batch, 6, key, sync_every=2)), full)
    import pytest
    with pytest.raises(ValueError, match="sync_every"):
        eng.generate(batch, 2, key, eos_id=eos, sync_every=0)


def test_prefill_rejects_zero_or_short_cache_len():
    """cache_len=0 used to fall through `cache_len or s` onto s silently."""
    import pytest
    from repro.models.model import build_model

    for name in ("llama3-8b", "minicpm3-4b"):   # attn_full and mla_full sites
        cfg = get_config(name, smoke=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((1, 8), jnp.int32)}
        with pytest.raises(ValueError, match="cache_len"):
            model.prefill(params, batch, cache_len=0)
        with pytest.raises(ValueError, match="shorter than the"):
            model.prefill(params, batch, cache_len=4)
        logits, _ = model.prefill(params, batch, cache_len=8)
        assert logits.shape[0] == 1


def test_serve_engine_validates_sampler_params():
    import pytest

    cfg = get_config("llama3-8b", smoke=True)
    for kw in (dict(bits_per_pass=0), dict(bits_per_pass=9),
               dict(top_p=1.5), dict(temperature=-1.0), dict(max_len=0)):
        with pytest.raises(ValueError):
            ServeEngine(cfg, None, **kw)
