"""Test-facing ulp oracle: run one scan-family op, score it against fp64.

Thin glue between the numeric core (:mod:`repro.analysis.ulp` — references,
conditioning scales, the ``ULP_COEFF`` bound table) and the ops under test.
Each ``*_case`` helper runs the op at a given ``(method, precision)``, scores
every element in fp32 ulps at the conditioning scale, and returns a
:class:`UlpReport`; :func:`assert_within_bound` is the single assertion the
precision tests and the benchmark sweep share, so the documented contract and
the gated number can never drift apart.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.analysis import ulp
from repro.core.linrec import linear_scan
from repro.core.scan import scan
from repro.core.segmented import segment_scan


@dataclasses.dataclass(frozen=True)
class UlpReport:
    """Scored run of one op: max/mean ulp error plus the applicable bound."""

    op: str
    method: str
    precision: str
    n: int
    max_ulp: float
    mean_ulp: float

    @property
    def bound(self) -> float:
        return ulp.ulp_bound(self.precision, self.n)

    def __str__(self) -> str:
        return (f"{self.op}/{self.method}/{self.precision}/n={self.n}: "
                f"max {self.max_ulp:.2f} mean {self.mean_ulp:.2f} "
                f"(bound {self.bound:.1f}) ulp")


def _report(op, method, precision, got, ref, scale) -> UlpReport:
    err = ulp.ulp_error(np.asarray(got), ref, scale)
    return UlpReport(op=op, method=method, precision=precision,
                     n=int(ref.shape[-1]),
                     max_ulp=float(np.max(err)) if err.size else 0.0,
                     mean_ulp=float(np.mean(err)) if err.size else 0.0)


def scan_case(x, *, method: str, precision: str, tile_s: int = 128,
              block_tiles: int = 8) -> UlpReport:
    """Score ``scan`` on fp32 ``x`` against the fp64 cumsum reference."""
    got = scan(jnp.asarray(x, jnp.float32), method=method,
               precision=precision, tile_s=tile_s, block_tiles=block_tiles)
    return _report("scan", method, precision, got,
                   ulp.scan_ref(x), ulp.scan_scale(x))


def linrec_case(a, b, *, method: str, precision: str, tile_s: int = 128,
                block_tiles: int = 8) -> UlpReport:
    """Score ``linear_scan`` against the fp64 sequential recurrence."""
    got = linear_scan(jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32),
                      method=method, precision=precision, tile_s=tile_s,
                      block_tiles=block_tiles)
    return _report("linear_scan", method, precision, got,
                   ulp.linrec_ref(a, b), ulp.linrec_scale(a, b))


def segment_scan_case(x, offsets, *, method: str, precision: str,
                      tile_s: int = 128, block_tiles: int = 8) -> UlpReport:
    """Score ``segment_scan`` against the per-segment fp64 reference."""
    got = segment_scan(jnp.asarray(x, jnp.float32),
                       jnp.asarray(offsets, jnp.int32), method=method,
                       precision=precision, tile_s=tile_s,
                       block_tiles=block_tiles)
    return _report("segment_scan", method, precision, got,
                   ulp.segment_scan_ref(x, offsets),
                   ulp.segment_scan_scale(x, offsets))


def assert_within_bound(report: UlpReport) -> None:
    """The one shared assertion: measured max ulp <= the documented bound."""
    assert report.max_ulp <= report.bound, str(report)
