"""Edge-case coverage for ``weighted_sample`` and ``compress`` (ISSUE 4).

Empty masks, all-true masks, single-element inputs and non-fp32 dtypes, plus
the deterministic ``u=`` override threaded through the sampling tail —
hypothesis-guarded in the ``test_multisplit.py`` style.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # property tests skip (not error) in minimal environments
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import compress, top_p_sample, weighted_sample

S = 8
METHODS_ALL = ["vector", "matmul", "kernel", "blocked"]


# ---------------------------------------------------------------------------
# compress edges
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS_ALL)
def test_compress_empty_mask(method):
    x = jnp.asarray([3, 1, 4, 1, 5], jnp.int32)
    z, c = compress(x, jnp.zeros(5, bool), method=method, tile_s=S,
                    fill_value=-9)
    assert int(c) == 0
    np.testing.assert_array_equal(np.asarray(z), [-9] * 5)


@pytest.mark.parametrize("method", METHODS_ALL)
def test_compress_all_true_mask(method):
    x = jnp.asarray([3, 1, 4, 1, 5], jnp.int32)
    z, c = compress(x, jnp.ones(5, bool), method=method, tile_s=S)
    assert int(c) == 5
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x))


@pytest.mark.parametrize("method", ["vector", "kernel"])
@pytest.mark.parametrize("keep", [True, False])
def test_compress_single_element(method, keep):
    x = jnp.asarray([7], jnp.int32)
    z, c = compress(x, jnp.asarray([keep]), method=method, tile_s=S)
    assert int(c) == int(keep)
    assert np.asarray(z).tolist() == ([7] if keep else [0])


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32, jnp.bfloat16,
                                   jnp.float16])
@pytest.mark.parametrize("method", ["vector", "matmul", "kernel"])
def test_compress_non_fp32_dtypes(dtype, method):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-8, 9, 37), dtype)
    m = jnp.asarray(rng.random(37) < 0.5)
    z, c = compress(x, m, method=method, tile_s=S)
    assert z.dtype == dtype
    want = np.asarray(x.astype(jnp.float32))[np.asarray(m)]
    np.testing.assert_array_equal(
        np.asarray(z.astype(jnp.float32))[:int(c)], want)
    assert np.all(np.asarray(z.astype(jnp.float32))[int(c):] == 0)


# ---------------------------------------------------------------------------
# weighted_sample edges
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS_ALL)
def test_weighted_sample_single_element(method):
    idx = weighted_sample(jnp.asarray([3.0]), jax.random.PRNGKey(0),
                          method=method, tile_s=S)
    assert int(idx) == 0 and idx.dtype == jnp.int32


@pytest.mark.parametrize("method", ["vector", "matmul"])
def test_weighted_sample_point_mass(method):
    """All mass on one index: every draw must return it."""
    w = jnp.zeros(17).at[11].set(2.5)
    for seed in range(4):
        assert int(weighted_sample(w, jax.random.PRNGKey(seed),
                                   method=method, tile_s=S)) == 11


def test_weighted_sample_all_zero_weights_clips_in_range():
    """Degenerate all-zero weights still return a valid index."""
    idx = weighted_sample(jnp.zeros(9), jax.random.PRNGKey(1), tile_s=S)
    assert 0 <= int(idx) < 9


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_weighted_sample_non_fp32_dtypes(dtype):
    """Sub-fp32 weights: the CDF accumulates in fp32 (accum dtype rules)."""
    w = jnp.asarray([0.0, 0.0, 1.0, 0.0], dtype)
    for method in ("vector", "matmul"):
        assert int(weighted_sample(w, jax.random.PRNGKey(2), method=method,
                                   tile_s=S)) == 2


def test_weighted_sample_u_override_and_cdf():
    """``u=`` replaces the key draw; ``cdf=`` skips the scan — same index."""
    w = jnp.asarray([1.0, 1.0, 1.0, 1.0])
    u = jnp.asarray([0.60])
    i1 = weighted_sample(w, None, u=u, tile_s=S)
    i2 = weighted_sample(w, None, u=u, tile_s=S,
                         cdf=jnp.cumsum(w))
    assert int(i1) == int(i2) == 2
    # batched: one uniform per row
    wb = jnp.stack([w, w])
    ub = jnp.asarray([[0.1], [0.9]])
    np.testing.assert_array_equal(
        np.asarray(weighted_sample(wb, None, u=ub, tile_s=S)), [0, 3])


def test_top_p_sample_u_override_is_deterministic():
    logits = jnp.asarray(
        np.random.default_rng(3).standard_normal((2, 64)) * 2, jnp.float32)
    u = jnp.asarray([[0.3], [0.7]])
    ref = top_p_sample(logits, None, p=0.9, u=u, tile_s=S)
    for method in ("vector", "matmul", "blocked"):
        got = top_p_sample(logits, None, p=0.9, method=method, u=u, tile_s=S)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# ---------------------------------------------------------------------------
# property-based (hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.booleans(), min_size=1, max_size=80),
           st.sampled_from(["vector", "matmul", "kernel"]))
    def test_compress_property(mask, method):
        m = np.asarray(mask)
        x = np.arange(m.size, dtype=np.int32)
        z, c = compress(jnp.asarray(x), jnp.asarray(m), method=method,
                        tile_s=S)
        assert int(c) == int(m.sum())
        np.testing.assert_array_equal(np.asarray(z)[:int(c)], x[m])
        assert np.all(np.asarray(z)[int(c):] == 0)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10**6), st.integers(1, 50))
    def test_weighted_sample_property_in_support(seed, n):
        """Sampled index always lands on a nonzero-weight position."""
        rng = np.random.default_rng(seed)
        w = rng.random(n) * (rng.random(n) < 0.5)
        if w.sum() == 0:
            w[rng.integers(0, n)] = 1.0
        idx = int(weighted_sample(jnp.asarray(w, jnp.float32),
                                  jax.random.PRNGKey(seed), tile_s=S))
        assert 0 <= idx < n
        assert w[idx] > 0

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed — property tests skipped")
    def test_operator_edges_property_placeholder():
        pass  # visible placeholder so missing hypothesis shows as a skip
