"""Autotune: table resolution, the documented fallback chain, and parity.

The contract under test (docs/architecture.md dispatch rule 8):

* ``method="auto"`` resolves pre-trace from the committed tuning table, so
  the traced jaxpr is *identical* to passing the resolved method explicitly;
* the fallback chain — override context > ``REPRO_SCAN_METHOD`` env > table
  bucket (largest breakpoint <= n, nearest bucket below the smallest) >
  dtype-nearest (silent) > backend/op/table fallbacks (warn once, degrade to
  ``"vector"``) — in that order;
* ``build_table`` is deterministic in its input rows (the CI drift gate).
"""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import autotune
from repro.core.autotune import (
    AUTO, AutotuneFallbackWarning, CONCRETE_METHODS, ENV_VAR, TUNED_OPS,
    build_table, load_table, maybe_resolve, method_override, parse_bench_rows,
    resolve_method, validate_table,
)
from repro.core.linrec import linear_scan
from repro.core.primitives import radix_sort, top_p_sample
from repro.core.scan import scan
from repro.core.segmented import segment_scan

# a tiny synthetic table exercising buckets, dtypes and fallbacks
TEST_TABLE = {
    "schema_version": 1,
    "provenance": {},
    "default_backend": "cpu",
    "backends": {
        "cpu": {
            "scan": {
                "float32": [[1024, "vector"], [8192, "matmul"]],
                "int8": [[1024, "kernel"]],
            },
            "sort": {"float32": [[512, "blocked"]]},
        },
    },
    "fallbacks": {"linear_scan": "matmul"},
}


@pytest.fixture(autouse=True)
def _fresh_state(monkeypatch):
    """Each test gets cleared warn-once state, no env override, a real table."""
    monkeypatch.delenv(ENV_VAR, raising=False)
    autotune._reset_for_testing()
    yield
    autotune._reset_for_testing()


def use_table(table):
    autotune._reset_for_testing(table)


# ---------------------------------------------------------------------------
# table lookup
# ---------------------------------------------------------------------------


def test_bucket_lookup_largest_breakpoint_leq_n():
    use_table(TEST_TABLE)
    r = lambda n: resolve_method("scan", n, "float32", backend="cpu")
    assert r(1024) == "vector"
    assert r(8191) == "vector"
    assert r(8192) == "matmul"
    assert r(1 << 20) == "matmul"


def test_nearest_bucket_below_smallest_breakpoint():
    use_table(TEST_TABLE)
    # n below the smallest measured length uses the first bucket, not vector
    assert resolve_method("scan", 4, "float32", backend="cpu") == "vector"
    assert resolve_method("sort", 1, "float32", backend="cpu") == "blocked"


def test_dtype_exact_then_nearest_silent():
    use_table(TEST_TABLE)
    assert resolve_method("scan", 2048, "int8", backend="cpu") == "kernel"
    # bfloat16 is unmeasured -> silently falls to float32 (no warning)
    with warnings.catch_warnings():
        warnings.simplefilter("error", AutotuneFallbackWarning)
        assert resolve_method("scan", 2048, "bfloat16", backend="cpu") == "vector"


def test_op_alias_collapses_onto_family():
    use_table(TEST_TABLE)
    # topk/radix_sort alias onto "sort"; cumsum onto "scan"
    assert resolve_method("topk", 600, "float32", backend="cpu") == "blocked"
    assert resolve_method("radix_sort", 600, "float32", backend="cpu") == "blocked"
    assert resolve_method("cumsum", 8192, "float32", backend="cpu") == "matmul"


def test_auto_never_returned():
    table = load_table()
    assert table is not None, "committed table must load from package data"
    for op in TUNED_OPS + tuple(autotune.OP_ALIASES):
        for n in (1, 512, 4096, 1 << 20):
            m = resolve_method(op, n, "float32", backend="cpu")
            assert m in CONCRETE_METHODS, (op, n, m)


# ---------------------------------------------------------------------------
# fallback chain
# ---------------------------------------------------------------------------


def test_missing_op_falls_back_to_explicit_entry_no_warning():
    use_table(TEST_TABLE)
    with warnings.catch_warnings():
        warnings.simplefilter("error", AutotuneFallbackWarning)
        assert resolve_method("linear_scan", 4096, "float32",
                              backend="cpu") == "matmul"


def test_missing_op_without_fallback_warns_once_and_uses_vector():
    use_table(TEST_TABLE)
    with pytest.warns(AutotuneFallbackWarning, match="segment_scan"):
        assert resolve_method("segment_scan", 4096, "float32",
                              backend="cpu") == "vector"
    with warnings.catch_warnings():  # second resolution is silent
        warnings.simplefilter("error", AutotuneFallbackWarning)
        assert resolve_method("segment_scan", 4096, "float32",
                              backend="cpu") == "vector"


def test_unknown_backend_warns_and_falls_to_default_backend():
    use_table(TEST_TABLE)
    with pytest.warns(AutotuneFallbackWarning, match="tpu"):
        assert resolve_method("scan", 8192, "float32",
                              backend="tpu") == "matmul"


def test_unloadable_table_warns_and_resolves_vector():
    use_table(None)
    assert resolve_method("scan", 8192, "float32", backend="cpu") == "vector"


def test_env_override_beats_table(monkeypatch):
    use_table(TEST_TABLE)
    monkeypatch.setenv(ENV_VAR, "blocked")
    assert resolve_method("scan", 8192, "float32", backend="cpu") == "blocked"
    monkeypatch.setenv(ENV_VAR, "auto")  # "auto" defers to the table
    assert resolve_method("scan", 8192, "float32", backend="cpu") == "matmul"
    monkeypatch.setenv(ENV_VAR, "nonsense")
    with pytest.raises(ValueError, match="nonsense"):
        resolve_method("scan", 8192, "float32", backend="cpu")


def test_context_override_beats_env(monkeypatch):
    use_table(TEST_TABLE)
    monkeypatch.setenv(ENV_VAR, "blocked")
    with method_override("kernel"):
        assert resolve_method("scan", 8192, "float32", backend="cpu") == "kernel"
    assert resolve_method("scan", 8192, "float32", backend="cpu") == "blocked"
    with pytest.raises(ValueError):
        with method_override("nonsense"):
            pass


def test_maybe_resolve_passes_concrete_methods_through():
    use_table(TEST_TABLE)
    for m in CONCRETE_METHODS:
        assert maybe_resolve(m, "scan", 8192, "float32") == m
    assert maybe_resolve(AUTO, "scan", 8192, "float32",
                         backend="cpu") == "matmul"


# ---------------------------------------------------------------------------
# jaxpr parity: auto traces identically to the method it resolves to
# ---------------------------------------------------------------------------


def _jaxpr(fn, *args):
    # object reprs inside jaxpr params carry memory addresses; mask them so
    # two traces of the same program compare equal.  Trace with staged checks
    # off: the identity under test is the dispatch layer's, and checkify
    # assigns each staged check a fresh global error code, so two otherwise
    # identical traces differ on the REPRO_CHECKS=1 CI leg.
    import re

    from repro.core import guards
    with guards.checks(False):
        return re.sub(r"0x[0-9a-f]+", "0x", str(jax.make_jaxpr(fn)(*args)))


@pytest.mark.parametrize("n", [64, 2048, 16384])
def test_scan_auto_jaxpr_identical(n):
    x = jnp.ones(n, jnp.float32)
    resolved = resolve_method("scan", n, x.dtype)
    assert _jaxpr(lambda a: scan(a, method="auto"), x) == \
        _jaxpr(lambda a: scan(a, method=resolved), x)


def test_linrec_auto_jaxpr_identical():
    a = jnp.full((2, 1024), 0.5, jnp.float32)
    b = jnp.ones((2, 1024), jnp.float32)
    resolved = resolve_method("linear_scan", 1024, jnp.float32)
    assert _jaxpr(lambda u, v: linear_scan(u, v, method="auto"), a, b) == \
        _jaxpr(lambda u, v: linear_scan(u, v, method=resolved), a, b)


def test_segmented_auto_jaxpr_identical():
    v = jnp.ones(512, jnp.float32)
    off = jnp.asarray([0, 100, 512], jnp.int32)
    resolved = resolve_method("segment_scan", 512, jnp.float32)
    assert _jaxpr(lambda x, o: segment_scan(x, o, method="auto"), v, off) == \
        _jaxpr(lambda x, o: segment_scan(x, o, method=resolved), v, off)


def test_sort_auto_jaxpr_identical():
    x = jnp.ones(256, jnp.int8)
    resolved = resolve_method("radix_sort", 256, jnp.int8)
    assert _jaxpr(lambda a: radix_sort(a, method="auto")[0], x) == \
        _jaxpr(lambda a: radix_sort(a, method=resolved)[0], x)


def test_top_p_auto_jaxpr_identical():
    logits = jnp.ones((2, 128), jnp.float32)
    key = jax.random.PRNGKey(0)
    resolved = resolve_method("top_p_sample", 128, jnp.float32)
    assert _jaxpr(lambda l, k: top_p_sample(l, k, method="auto"), logits, key) \
        == _jaxpr(lambda l, k: top_p_sample(l, k, method=resolved), logits, key)


def test_auto_bit_parity_with_resolved():
    # int8 cumsum is exact; auto must be bit-identical to its resolution
    x = jnp.asarray([3, -1, 7, 0, 2, 5, -4, 1] * 32, jnp.int8)
    resolved = resolve_method("scan", x.shape[0], x.dtype)
    assert jnp.array_equal(scan(x, method="auto"), scan(x, method=resolved))


def test_env_override_changes_resolution_under_jit(monkeypatch):
    # resolution is pre-trace: the env var picks the path before jit sees it
    use_table(TEST_TABLE)
    x = jnp.ones(8192, jnp.float32)
    monkeypatch.setenv(ENV_VAR, "vector")
    j_env = _jaxpr(lambda a: scan(a, method="auto"), x)
    monkeypatch.delenv(ENV_VAR)
    assert j_env == _jaxpr(lambda a: scan(a, method="vector"), x)


# ---------------------------------------------------------------------------
# precision x method resolution (docs/architecture.md dispatch rule 9)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("precision", ["compensated", "fast"])
def test_auto_resolution_independent_of_precision(precision):
    # precision never steers method="auto": the table lookup is the same, and
    # auto traces identically to passing the resolved method explicitly at
    # that same precision
    use_table(TEST_TABLE)
    x = jnp.ones(8192, jnp.float32)
    resolved = resolve_method("scan", x.shape[0], x.dtype, backend="cpu")
    assert resolved == "matmul"  # the table entry, unmoved by precision
    assert _jaxpr(lambda a: scan(a, method="auto", precision=precision), x) \
        == _jaxpr(lambda a: scan(a, method=resolved, precision=precision), x)


def test_auto_resolution_to_vector_degrades_precision_silently():
    # auto may land on vector (small n); a non-default precision then degrades
    # to "highest" rather than erroring — only *explicit* method="vector"
    # rejects precision (next test)
    use_table(TEST_TABLE)
    x = jnp.ones(64, jnp.float32)
    assert resolve_method("scan", 64, x.dtype, backend="cpu") == "vector"
    assert _jaxpr(lambda a: scan(a, method="auto", precision="compensated"), x) \
        == _jaxpr(lambda a: scan(a, method="vector"), x)


@pytest.mark.parametrize("precision", ["compensated", "fast"])
def test_explicit_vector_rejects_precision(precision):
    x = jnp.ones(64, jnp.float32)
    with pytest.raises(ValueError, match="matmul-engine"):
        scan(x, method="vector", precision=precision)
    a = jnp.full((2, 64), 0.5, jnp.float32)
    with pytest.raises(ValueError, match="matmul-engine"):
        linear_scan(a, a, method="vector", precision=precision)
    off = jnp.asarray([0, 10, 64], jnp.int32)
    with pytest.raises(ValueError, match="matmul-engine"):
        segment_scan(x, off, method="vector", precision=precision)


# ---------------------------------------------------------------------------
# table build/validate (the pieces the CI drift gate runs)
# ---------------------------------------------------------------------------


def test_build_table_deterministic_and_valid():
    rows = [
        {"name": "scan_pipeline/vector/float32/n=512", "us_per_call": 1.0},
        {"name": "scan_pipeline/matmul/float32/n=512", "us_per_call": 2.0},
        {"name": "scan_pipeline/vector/float32/n=4096", "us_per_call": 9.0},
        {"name": "scan_pipeline/matmul/float32/n=4096", "us_per_call": 3.0},
        {"name": "scan_pipeline/memcpy/float32/n=512", "us_per_call": 0.5},
        {"name": "scan_pipeline/auto/float32/n=512", "us_per_call": 1.0},
    ]
    t1 = build_table(rows, backend="cpu")
    t2 = build_table(list(reversed(rows)), backend="cpu")
    assert t1 == t2
    assert validate_table(t1) == []
    assert t1["backends"]["cpu"]["scan"]["float32"] == \
        [[512, "vector"], [4096, "matmul"]]
    # memcpy and auto rows never contribute measurements
    assert parse_bench_rows(rows[-2:]) == []
    # unmeasured tuned ops get explicit vector fallbacks
    assert t1["fallbacks"]["sort"] == "vector"


def test_committed_table_valid_and_matches_baselines():
    table = load_table()
    assert table is not None
    assert validate_table(table) == []
    # the same check tools/tune.py --check (the tuning-table CI job) runs:
    # regenerating from the committed baselines must reproduce the table
    base = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baseline")
    rows = []
    for f in sorted(os.listdir(base)):
        if f.startswith("BENCH_") and f.endswith(".json"):
            with open(os.path.join(base, f)) as fh:
                rows.extend(json.load(fh))
    regen = build_table(rows, backend=table["default_backend"])
    strip = lambda t: {k: v for k, v in t.items() if k != "provenance"}
    assert strip(regen) == strip(table)


def test_validate_table_catches_bad_tables():
    assert validate_table({"schema_version": 99}) != []
    bad = json.loads(json.dumps(TEST_TABLE))
    bad["backends"]["cpu"]["scan"]["float32"] = [[8192, "matmul"], [1024, "vector"]]
    assert any("ascending" in p for p in validate_table(bad))
    bad2 = json.loads(json.dumps(TEST_TABLE))
    bad2["fallbacks"]["linear_scan"] = "warp"
    assert any("warp" in p for p in validate_table(bad2))
