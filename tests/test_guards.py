"""Guardrails layer (ISSUE 8): resolution order, checks, probes, validators.

The three contracts under test, in the order ``docs/architecture.md`` rule 10
documents them:

1. the ``nonfinite`` policy resolves ctx > env > call-site, pre-trace;
2. staged checks are a Python no-op when off — guarded operators trace to
   jaxprs **identical** to :func:`repro.core.guards.guards_disabled`;
3. ``kernel``/``blocked`` dispatch probes lowering once and degrades through
   the tuning-table fallbacks with a warn-once ``ProbeFallbackWarning``.
"""
import re

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.experimental import checkify

from repro.core import guards
from repro.core.autotune import _WARNED
from repro.core.linrec import linear_scan
from repro.core.primitives import radix_sort, split, top_p_sample, \
    weighted_sample
from repro.core.scan import scan
from repro.core.segmented import segment_scan, segment_top_p_sample


OFF = jnp.asarray([0, 3, 5])
X5 = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])


def _jaxpr(fn, *args):
    """Jaxpr text with object ids stripped (stable across traces)."""
    return re.sub(r"0x[0-9a-f]+", "", str(jax.make_jaxpr(fn)(*args)))


# ---------------------------------------------------------------------------
# nonfinite policy resolution (rule 10 mirrors rules 8/9)
# ---------------------------------------------------------------------------


def test_resolution_order_ctx_beats_env_beats_arg(monkeypatch):
    monkeypatch.setenv(guards.ENV_VAR, "raise")
    assert guards.resolve_nonfinite("propagate") == "raise"   # env > arg
    with guards.nonfinite_override("sanitize"):               # ctx > env
        assert guards.resolve_nonfinite("propagate") == "sanitize"
    monkeypatch.delenv(guards.ENV_VAR)
    assert guards.resolve_nonfinite("sanitize") == "sanitize"  # arg
    assert guards.resolve_nonfinite() == "propagate"


def test_unknown_policy_rejected_everywhere(monkeypatch):
    with pytest.raises(ValueError, match="nonfinite"):
        guards.resolve_nonfinite("explode")
    with pytest.raises(ValueError, match="nonfinite"):
        with guards.nonfinite_override("explode"):
            pass
    monkeypatch.setenv(guards.ENV_VAR, "explode")
    with pytest.raises(ValueError, match=guards.ENV_VAR):
        guards.resolve_nonfinite()


def test_guards_disabled_forces_propagate_and_no_checks(monkeypatch):
    monkeypatch.setenv(guards.CHECKS_ENV_VAR, "1")
    with guards.nonfinite_override("raise"):
        with guards.guards_disabled():
            assert guards.resolve_nonfinite() == "propagate"
            assert not guards.checks_enabled()
            assert not guards.guards_active()
        assert guards.resolve_nonfinite() == "raise"
    assert guards.checks_enabled()


def test_env_var_drives_operator_behaviour(monkeypatch):
    bad = jnp.asarray([1.0, jnp.nan, 3.0])
    monkeypatch.setenv(guards.ENV_VAR, "sanitize")
    assert scan(bad).tolist() == [1.0, 1.0, 4.0]
    monkeypatch.setenv(guards.ENV_VAR, "raise")
    with pytest.raises(guards.NonFiniteError):
        scan(bad)


# ---------------------------------------------------------------------------
# checks: eager + staged assertions
# ---------------------------------------------------------------------------


def test_guard_check_noop_when_off():
    guard_thunk_ran = []
    with guards.checks(False):   # pin off even on the REPRO_CHECKS=1 CI leg
        guards.guard_check(lambda: guard_thunk_ran.append(1),
                           "never evaluated")
    assert not guard_thunk_ran


def test_guard_check_eager_concrete_raises():
    with guards.checks():
        with pytest.raises(checkify.JaxRuntimeError, match="bad scalar"):
            guards.guard_check(False, "bad scalar")
        guards.guard_check(True, "fine")


def test_guard_check_staged_fires_through_checked():
    def f(x):
        guards.guard_check(lambda: jnp.all(x > 0), "x must be positive")
        return x * 2

    with guards.checks():
        out = guards.checked(f)(jnp.asarray([1.0, 2.0]))
        assert out.tolist() == [2.0, 4.0]
        with pytest.raises(checkify.JaxRuntimeError, match="positive"):
            guards.checked(f)(jnp.asarray([1.0, -2.0]))


def test_traced_offsets_csr_check_fires_in_jit():
    # jit makes the offsets genuine tracers; concrete offsets are caught
    # eagerly by the ValueError path instead (test_validate_offsets_concrete)
    def f(values, offsets):
        return segment_scan(values, offsets)

    with guards.checks():
        cf = guards.checked(jax.jit(f))
        good = cf(X5, OFF)
        assert good.shape == X5.shape
        with pytest.raises(checkify.JaxRuntimeError, match="CSR"):
            cf(X5, jnp.asarray([0, 4, 2]))


def test_checks_env_var(monkeypatch):
    monkeypatch.setenv(guards.CHECKS_ENV_VAR, "1")
    assert guards.checks_enabled()
    with guards.checks(False):   # ctx wins over env
        assert not guards.checks_enabled()
    monkeypatch.delenv(guards.CHECKS_ENV_VAR)
    assert not guards.checks_enabled()


# ---------------------------------------------------------------------------
# jaxpr identity: guarded defaults == guards_disabled (zero-overhead gate)
# ---------------------------------------------------------------------------


IDENTITY_CASES = [
    ("scan", lambda x: scan(x), X5),
    ("linrec", lambda x: linear_scan(x, x), X5),
    ("segment_scan", lambda x: segment_scan(x, OFF), X5),
    ("weighted_sample",
     lambda x: weighted_sample(x, None, u=jnp.asarray(0.5)), X5),
    ("top_p",
     lambda x: top_p_sample(x[None], None, p=0.9,
                            u=jnp.asarray([[0.5]])), X5),
    ("segment_top_p",
     lambda x: segment_top_p_sample(x, OFF, p=0.9,
                                    u=jnp.asarray([[0.5], [0.5]])), X5),
]


@pytest.mark.parametrize("name,fn,arg",
                         IDENTITY_CASES, ids=[c[0] for c in IDENTITY_CASES])
def test_jaxpr_identity_guarded_vs_disabled(name, fn, arg):
    with guards.checks(False):   # the documented checks-off contract
        guarded = _jaxpr(fn, arg)
    with guards.guards_disabled():
        bare = _jaxpr(fn, arg)
    assert guarded == bare, f"{name}: guards added ops to the default trace"


# ---------------------------------------------------------------------------
# backend capability probe
# ---------------------------------------------------------------------------


def test_probe_lowering_succeeds_and_caches():
    backend = jax.default_backend()
    assert guards.probe_lowering("scan", "kernel", backend=backend)
    assert (backend, "scan", "kernel") in guards._PROBE_CACHE


def test_forced_probe_failure_degrades_with_single_warning():
    _WARNED.clear()
    with guards.force_probe_failure("scan", "kernel"):
        with pytest.warns(guards.ProbeFallbackWarning, match="rule 10"):
            assert guards.ensure_available("kernel", "scan") == "vector"
        # warn-once: a second degrade of the same key is silent
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("error")
            assert guards.ensure_available("kernel", "scan") == "vector"
    _WARNED.clear()
    # outside the block the real (passing) probe result is restored
    assert guards.ensure_available("kernel", "scan") == "kernel"


def test_forced_probe_failure_through_public_entry():
    _WARNED.clear()
    x = jnp.asarray([3, 1, 4, 1, 5], jnp.int32)
    f = jnp.asarray([1, 0, 1, 0, 1], jnp.int8)
    with guards.force_probe_failure():
        with pytest.warns(guards.ProbeFallbackWarning):
            z, ind, cnt = split(x, f, method="kernel", tile_s=8)
    _WARNED.clear()
    zr, indr, cntr = split(x, f, method="vector", tile_s=8)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(zr))
    assert int(cnt) == int(cntr)


def test_probe_bypassed_under_guards_disabled():
    with guards.force_probe_failure():
        with guards.guards_disabled():
            assert guards.ensure_available("kernel", "scan") == "kernel"


def test_probe_family_collapse():
    assert guards._probe_family("sort", "blocked") == "scan"
    assert guards._probe_family("radix_sort", "kernel") == "sort"
    assert guards._probe_family("linear_scan", "blocked") == "linear_scan"


# ---------------------------------------------------------------------------
# validators
# ---------------------------------------------------------------------------


def test_validate_axis_rejects_out_of_bounds():
    assert guards.validate_axis(-1, 2, op="scan") == 1
    with pytest.raises(ValueError, match="axis"):
        guards.validate_axis(5, 2, op="scan")
    with pytest.raises(ValueError, match="axis"):
        scan(jnp.ones((2, 3)), axis=7)
    with pytest.raises(ValueError, match="axis"):
        linear_scan(jnp.ones(4), jnp.ones(4), axis=-2)


def test_validate_bits_per_pass():
    with pytest.raises(ValueError, match="bits_per_pass"):
        radix_sort(jnp.asarray([3, 1, 2], jnp.int32), bits_per_pass=0)
    with pytest.raises(ValueError, match="bits_per_pass"):
        radix_sort(jnp.asarray([3, 1, 2], jnp.int32), bits_per_pass=9)


@pytest.mark.parametrize("bad,err", [
    ([1, 3, 5], ValueError),          # offsets[0] != 0
    ([0, 3, 9], ValueError),          # offsets[-1] != n
    ([0, 4, 2, 5], ValueError),       # decreasing
    ([[0, 3, 5]], ValueError),        # 2-D
])
def test_validate_offsets_concrete(bad, err):
    with pytest.raises(err):
        segment_scan(X5, jnp.asarray(bad))


def test_validate_offsets_traced_pass_through():
    out = jax.jit(lambda v, o: segment_scan(v, o))(X5, OFF)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(segment_scan(X5, OFF)))


def test_sampler_param_validation():
    logits = jnp.asarray([[0.0, 1.0, 2.0]])
    u = jnp.asarray([[0.5]])
    with pytest.raises(ValueError, match="p must"):
        top_p_sample(logits, None, p=1.5, u=u)
    with pytest.raises(ValueError, match="p must"):
        top_p_sample(logits, None, p=float("nan"), u=u)
    with pytest.raises(ValueError, match="temperature"):
        top_p_sample(logits, None, temperature=-1.0, u=u)
    with pytest.raises(ValueError, match="temperature"):
        top_p_sample(logits, None, temperature=float("inf"), u=u)


def test_kernel_entry_validators():
    from repro.kernels.scan_mm import scan_tiles
    from repro.kernels.split_mm import multi_split_tiles, split_tiles

    with pytest.raises(ValueError, match="variant"):
        scan_tiles(jnp.ones(8), variant="scanul3", s=2)
    with pytest.raises(ValueError, match="must match"):
        split_tiles(jnp.ones(8), jnp.ones(7), s=2)
    with pytest.raises(ValueError, match="num_buckets"):
        multi_split_tiles(jnp.ones(8), jnp.zeros(8, jnp.int32),
                          num_buckets=0, s=2)


def test_apply_nonfinite_policies():
    x = jnp.asarray([1.0, jnp.inf, jnp.nan])
    assert guards.apply_nonfinite(x, "propagate", op="t") is x
    assert guards.apply_nonfinite(
        x, "sanitize", op="t", identity=7.0).tolist() == [1.0, 7.0, 7.0]
    with pytest.raises(guards.NonFiniteError):
        guards.apply_nonfinite(x, "raise", op="t")
    ints = jnp.asarray([1, 2, 3], jnp.int32)
    assert guards.apply_nonfinite(ints, "raise", op="t") is ints
