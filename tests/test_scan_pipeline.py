"""Blocked three-phase scan pipeline (paper §4): parity with method="vector".

Bit-identity strategy: float addition is associative over integer-valued
payloads whose partial sums stay exactly representable (|sum| < 2^24 for an
fp32 accumulator), so any summation order — jnp.cumsum, matmul tiles, the
blocked pipeline — must produce the *same bits*.  That lets the parity tests
assert exact equality for fp32 and bf16, not just int8, across ragged lengths
and block shapes.  Gaussian payloads are additionally checked to tolerance.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import scan
from repro.core.primitives import radix_sort, split, top_p_sample
from repro.kernels.scan_pipeline import (
    block_partial_sums, blocked_scan, carry_scan,
)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

DTYPES = ("float32", "bfloat16", "int8")
# Ragged on purpose: primes, one-off-from-block-multiples, sub-tile lengths.
LENGTHS = (1, 5, 63, 64, 257, 1000, 4096, 20000)


def _payload(dtype, n, seed=0):
    """Integer-valued payload in [-3, 3] — exact under any summation order."""
    ints = np.random.default_rng(seed).integers(-3, 4, n)
    if dtype == "int8":
        return jnp.asarray(ints, jnp.int8)
    return jnp.asarray(ints.astype(np.float32), jnp.dtype(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n", LENGTHS)
@pytest.mark.parametrize("s,block_tiles", [(8, 1), (8, 4), (16, 2)])
def test_blocked_bit_identical_to_vector(dtype, n, s, block_tiles):
    x = _payload(dtype, n, seed=n * s + block_tiles)
    got = scan(x, method="blocked", tile_s=s, block_tiles=block_tiles)
    ref = scan(x, method="vector")
    assert got.dtype == ref.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("variant", ["scanu", "scanul1"])
def test_blocked_variants_bit_identical(variant):
    x = _payload("float32", 5000, seed=7)
    got = scan(x, method="blocked", variant=variant, tile_s=8, block_tiles=2)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(scan(x, method="vector")))


@pytest.mark.parametrize("variant", ["scanu", "scanul1"])
def test_blocked_gaussian_close(variant):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((3, 2777)), jnp.float32)
    got = scan(x, method="blocked", variant=variant, tile_s=16, block_tiles=2)
    np.testing.assert_allclose(np.asarray(got),
                               np.cumsum(np.asarray(x, np.float64), -1),
                               rtol=1e-4, atol=1e-3)


def test_blocked_axis_exclusive_reverse():
    """The scan() wrapper plumbing (axis move / flip / shift) over the pipeline."""
    x = _payload("float32", 3 * 257, seed=11).reshape(3, 257)
    kw = dict(method="blocked", tile_s=8, block_tiles=2)
    np.testing.assert_array_equal(
        np.asarray(scan(x, axis=0, **kw)),
        np.asarray(scan(x, axis=0, method="vector")))
    np.testing.assert_array_equal(
        np.asarray(scan(x, exclusive=True, **kw)),
        np.asarray(scan(x, exclusive=True, method="vector")))
    np.testing.assert_array_equal(
        np.asarray(scan(x, reverse=True, **kw)),
        np.asarray(scan(x, reverse=True, method="vector")))


def test_blocked_carry_across_many_blocks():
    """Carries must thread through a long chain of blocks exactly."""
    x = jnp.ones((2, 8 * 8 * 40), jnp.float32)
    out = scan(x, method="blocked", tile_s=8, block_tiles=1)
    np.testing.assert_allclose(np.asarray(out)[:, -1], 8 * 8 * 40)


def test_phase_kernels_individually():
    """Phase 1 (block sums) and phase 2 (carry scan) in isolation."""
    rng = np.random.default_rng(0)
    blocks = jnp.asarray(rng.integers(-3, 4, (2, 5, 4, 8)), jnp.int8)
    sums = block_partial_sums(blocks)
    assert sums.shape == (2, 5) and sums.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(sums), np.asarray(blocks, np.int32).sum((2, 3)))
    carries = carry_scan(sums)
    ref = np.cumsum(np.asarray(sums), -1) - np.asarray(sums)   # exclusive
    np.testing.assert_array_equal(np.asarray(carries), ref)


def test_blocked_scan_rejects_unknown_variant():
    with pytest.raises(ValueError):
        blocked_scan(jnp.ones(8), variant="nope")
    with pytest.raises(ValueError):
        scan(jnp.ones(8), method="nope")


def test_operators_on_blocked_method():
    """split / radix_sort / top_p_sample accept method="blocked" and match."""
    import jax
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    f = jnp.asarray(rng.random(1000) < 0.5)
    zv, iv, kv = split(x, f, method="vector")
    zb, ib, kb = split(x, f, method="blocked", tile_s=8)
    np.testing.assert_array_equal(np.asarray(zv), np.asarray(zb))
    np.testing.assert_array_equal(np.asarray(iv), np.asarray(ib))
    assert int(kv) == int(kb)
    keys = jnp.asarray(rng.standard_normal(257), jnp.bfloat16)
    _, pv = radix_sort(keys, method="vector")
    _, pb = radix_sort(keys, method="blocked", tile_s=8)
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(pb))
    logits = jnp.asarray(rng.standard_normal((2, 512)) * 3, jnp.float32)
    tv = top_p_sample(logits, jax.random.PRNGKey(0), method="vector", tile_s=8)
    tb = top_p_sample(logits, jax.random.PRNGKey(0), method="blocked", tile_s=8)
    np.testing.assert_array_equal(np.asarray(tv), np.asarray(tb))


def test_mcscan_blocked_multi_device():
    """mcscan's default per-device path is the fused pipeline; parity on a CPU
    mesh (device count is locked at jax init, so run in a subprocess)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = """
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import mcscan
        from repro.utils.compat import make_mesh
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(2)
        # fp32, integer-valued -> bit-identical to the vector scan
        xi = rng.integers(-3, 4, (2, 4096)).astype(np.float32)
        out = mcscan(jnp.asarray(xi), mesh, "data", tile_s=8)
        np.testing.assert_array_equal(np.asarray(out), np.cumsum(xi, -1))
        # int8 mask -> int32, exact
        m = (rng.random((1, 8192)) < 0.5).astype(np.int8)
        om = mcscan(jnp.asarray(m), mesh, "data", tile_s=8)
        assert om.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(om),
                                      np.cumsum(m.astype(np.int32), -1))
        # gaussian fp32 to tolerance, explicit blocked method + batch axis
        mesh2 = make_mesh((4, 2), ("data", "model"))
        xg = rng.standard_normal((2, 4096)).astype(np.float32)
        og = mcscan(jnp.asarray(xg), mesh2, "data", method="blocked",
                    tile_s=16, batch_axis_name="model")
        np.testing.assert_allclose(np.asarray(og), np.cumsum(xg, -1),
                                   rtol=1e-4, atol=1e-3)
        # still exactly ONE small all-gather on the blocked path
        f = jax.jit(lambda a: mcscan(a, mesh, "data", tile_s=8))
        txt = f.lower(jnp.asarray(xg[:1])).compile().as_text()
        ag = [l for l in txt.splitlines() if "= " in l and "all-gather(" in l]
        assert len(ag) == 1, ag
        print("MCSCAN-PIPELINE-OK")
        """
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=520, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "MCSCAN-PIPELINE-OK" in r.stdout
