"""Fault injection (ISSUE 8): every fault lands on a documented contract.

Each test injects one fault class through a public operator and asserts the
outcome :func:`repro.analysis.faults.classify` reports is the contracted one
— ``value``/``type`` (eager validation), ``nonfinite`` (policy raise),
``checkified`` (staged assertion), ``degraded`` (probe fallback), or ``ok``
(propagate / sanitize semantics).  Anything else — a crash inside a kernel, a
silent wrong answer — fails the suite.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.faults import (
    OUTCOMES, adversarial_params, checks, classify, corrupt_offsets,
    force_probe_failure, inject_nonfinite,
)
from repro.core import guards
from repro.core.linrec import linear_scan
from repro.core.primitives import split, top_p_sample, weighted_sample
from repro.core.scan import scan
from repro.core.segmented import segment_scan, segment_top_p_sample

OFF = jnp.asarray([0, 3, 5])
X5 = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])
U2 = jnp.asarray([[0.5], [0.5]])


# ---------------------------------------------------------------------------
# non-finite payloads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["nan", "inf", "-inf", "extreme"])
def test_nonfinite_payload_propagates_by_default(kind):
    x = inject_nonfinite(X5, kind, frac=0.2, seed=3)
    with checks(False):   # propagate is the *unchecked* IEEE contract
        outcome, out = classify(scan, x)
    assert outcome == "ok"
    if kind != "extreme":   # extreme payloads are finite until accumulated
        assert not bool(jnp.isfinite(out).all())


@pytest.mark.parametrize("kind", ["nan", "inf", "-inf"])
@pytest.mark.parametrize("op", ["scan", "linrec", "segment_scan"])
def test_nonfinite_payload_raises_under_policy(kind, op):
    x = inject_nonfinite(X5, kind, frac=0.2, seed=4)
    fns = {
        "scan": lambda v: scan(v, nonfinite="raise"),
        "linrec": lambda v: linear_scan(v, v, nonfinite="raise"),
        "segment_scan": lambda v: segment_scan(v, OFF, nonfinite="raise"),
    }
    outcome, detail = classify(fns[op], x)
    assert outcome == "nonfinite", (op, kind, detail)


@pytest.mark.parametrize("kind", ["nan", "inf"])
def test_nonfinite_payload_sanitizes_to_identity(kind):
    x = inject_nonfinite(X5, kind, frac=0.2, seed=5)
    outcome, out = classify(scan, x, nonfinite="sanitize")
    assert outcome == "ok"
    assert bool(jnp.isfinite(out).all())
    ref = scan(jnp.where(jnp.isfinite(x), x, 0.0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


def test_nan_logits_sampler_contracts():
    logits = inject_nonfinite(jnp.zeros((2, 8)), "nan", frac=0.2, seed=6)
    u = jnp.asarray([[0.5], [0.5]])
    with checks(False):
        outcome, _ = classify(top_p_sample, logits, None, u=u)
    assert outcome == "ok"                                    # propagate
    outcome, _ = classify(top_p_sample, logits, None, u=u, nonfinite="raise")
    assert outcome == "nonfinite"
    outcome, tok = classify(top_p_sample, logits, None, u=u,
                            nonfinite="sanitize")
    assert outcome == "ok"
    assert tok.shape == (2,) and bool(jnp.all(tok >= 0))


def test_checkified_cdf_assertion_fires():
    """The staged finite-CDF check catches NaN weights under REPRO_CHECKS."""
    w = jnp.asarray([0.2, float("nan"), 0.1])

    def f(wv):
        return weighted_sample(wv, None, u=jnp.asarray(0.5))

    with checks():
        outcome, detail = classify(guards.checked(f), w)
    assert outcome == "checkified", detail


# ---------------------------------------------------------------------------
# corrupted offsets
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,expected", [
    ("unsorted", "value"), ("negative", "value"), ("overrun", "value"),
    ("head", "value"), ("float", "type"),
])
def test_corrupted_offsets_rejected_eagerly(mode, expected):
    bad = corrupt_offsets(OFF, mode)
    if mode == "float":
        # the public entries cast concrete offsets to int32 on the way in;
        # the validator itself owns the TypeError contract
        with pytest.raises(TypeError):
            guards.validate_offsets(bad, 5, op="segment_scan")
        return
    outcome, detail = classify(segment_scan, X5, bad)
    assert outcome == expected, (mode, detail)


@pytest.mark.parametrize("mode", ["unsorted", "negative", "overrun", "head"])
def test_corrupted_offsets_traced_hit_checkified_contract(mode):
    """Under jit the offsets are tracers: the CSR check stages instead."""
    bad = corrupt_offsets(OFF, mode)

    def f(values, offsets):
        return segment_scan(values, offsets)

    with checks():
        outcome, detail = classify(guards.checked(jax.jit(f)), X5, bad)
    assert outcome == "checkified", (mode, detail)


# ---------------------------------------------------------------------------
# adversarial sampler parameters
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("which,expected", [
    ("p_over", "value"), ("p_under", "value"), ("p_nan", "value"),
    ("temp_negative", "value"), ("temp_nan", "value"), ("temp_inf", "value"),
    ("temp_zero", "ok"),
])
def test_adversarial_sampler_params(which, expected):
    logits = jnp.asarray([[0.0, 1.0, 5.0]])
    kw = adversarial_params(which)
    outcome, detail = classify(top_p_sample, logits, None,
                               u=jnp.asarray([[0.5]]), **kw)
    assert outcome == expected, (which, detail)
    out2, detail2 = classify(segment_top_p_sample, logits[0],
                             jnp.asarray([0, 3]), None,
                             u=jnp.asarray([[0.5]]), **kw)
    assert out2 == expected, (which, detail2)


def test_unsupported_sort_dtype_hits_type_contract():
    """float64 keys have no sortable-int encoding: a documented TypeError."""
    from repro.core.primitives import radix_sort

    x = jnp.asarray([3.0, 1.0, 2.0]).astype(jnp.float32)
    outcome, _ = classify(radix_sort, x)
    assert outcome == "ok"
    outcome, detail = classify(radix_sort, np.asarray([3.0, 1.0], np.float64))
    assert outcome == "type", detail


def test_temperature_zero_is_argmax():
    logits = jnp.asarray([[0.0, 9.0, 1.0], [3.0, 0.0, 0.0]])
    tok = top_p_sample(logits, None, temperature=0.0)
    assert tok.tolist() == [1, 0]


# ---------------------------------------------------------------------------
# lowering failures degrade, not crash
# ---------------------------------------------------------------------------


def test_lowering_failure_degrades_scan():
    from repro.core.autotune import _WARNED
    _WARNED.clear()
    with force_probe_failure():
        outcome, out = classify(scan, X5, method="kernel", tile_s=8)
    _WARNED.clear()
    assert outcome == "degraded"


def test_lowering_failure_result_matches_fallback():
    from repro.core.autotune import _WARNED
    _WARNED.clear()
    x = jnp.asarray(np.random.default_rng(0).integers(0, 9, 64), jnp.int32)
    f = jnp.asarray(np.random.default_rng(1).integers(0, 2, 64), jnp.int8)
    with force_probe_failure():
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            z, ind, cnt = split(x, f, method="kernel", tile_s=8)
    _WARNED.clear()
    zr, indr, cntr = split(x, f, method="vector", tile_s=8)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(zr))
    np.testing.assert_array_equal(np.asarray(ind), np.asarray(indr))
    assert int(cnt) == int(cntr)


def test_outcomes_closed_set():
    assert set(OUTCOMES) == {"ok", "value", "type", "nonfinite",
                             "checkified", "degraded"}
