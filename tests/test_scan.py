"""Core matmul-scan correctness: paper Alg. 1 (ScanU), Alg. 2/Eq. 1 (ScanUL1),
multi-level blocking, dtype specializations, exclusive/reverse/axis handling."""
import numpy as np
import jax.numpy as jnp
import pytest

try:  # property tests skip (not error) in minimal environments
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import scan, tile_scan_scanu, tile_scan_scanul1


@pytest.mark.parametrize("variant", ["scanu", "scanul1"])
@pytest.mark.parametrize("n", [1, 2, 17, 128, 1000, 16384, 40000])
@pytest.mark.parametrize("s", [8, 32, 128])
def test_scan_matches_cumsum(variant, n, s):
    rng = np.random.default_rng(n * s)
    x = rng.standard_normal(n).astype(np.float32)
    out = scan(jnp.asarray(x), method="matmul", variant=variant, tile_s=s)
    np.testing.assert_allclose(np.asarray(out), np.cumsum(x),
                               rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("variant", ["scanu", "scanul1"])
def test_tile_identities(variant):
    """Eq. 1: scan(z) = A@U + L⁻@A@1 for a single s² tile."""
    rng = np.random.default_rng(0)
    s = 16
    a = jnp.asarray(rng.standard_normal((3, s, s)), jnp.float32)
    fn = tile_scan_scanu if variant == "scanu" else tile_scan_scanul1
    out = fn(a)
    ref = np.cumsum(np.asarray(a).reshape(3, s * s), axis=1).reshape(3, s, s)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4, atol=1e-3)


def test_int8_mask_scan_accumulates_int32():
    """The paper's int8 -> int32 cube-unit specialization."""
    rng = np.random.default_rng(1)
    m = (rng.random(5000) < 0.3).astype(np.int8)
    out = scan(jnp.asarray(m), method="matmul", tile_s=32)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.cumsum(m.astype(np.int32)))


def test_bf16_accumulates_f32():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal(512), jnp.bfloat16)
    out = scan(x, method="matmul", tile_s=16)
    assert out.dtype == jnp.float32


def test_exclusive_reverse_axis_batched():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((3, 257)).astype(np.float32)
    ex = np.concatenate([np.zeros((3, 1)), np.cumsum(x, 1)[:, :-1]], 1)
    np.testing.assert_allclose(
        np.asarray(scan(jnp.asarray(x), exclusive=True, tile_s=16)), ex,
        rtol=1e-4, atol=1e-4)
    rev = np.flip(np.cumsum(np.flip(x, 1), 1), 1)
    np.testing.assert_allclose(
        np.asarray(scan(jnp.asarray(x), reverse=True, tile_s=16)), rev,
        rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(scan(jnp.asarray(x), axis=0, tile_s=16)), np.cumsum(x, 0),
        rtol=1e-4, atol=1e-4)


def test_vector_baseline_agrees():
    rng = np.random.default_rng(4)
    x = rng.standard_normal(777).astype(np.float32)
    a = scan(jnp.asarray(x), method="vector")
    b = scan(jnp.asarray(x), method="matmul", tile_s=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-3)


# ---- property-based: scan is the discrete integral (hypothesis) ----


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.floats(-100, 100, allow_nan=False, width=32),
                    min_size=1, max_size=600),
           st.sampled_from([8, 16, 128]),
           st.sampled_from(["scanu", "scanul1"]))
    def test_property_matches_numpy(xs, s, variant):
        x = np.asarray(xs, np.float32)
        out = np.asarray(scan(jnp.asarray(x), method="matmul", variant=variant,
                              tile_s=s))
        np.testing.assert_allclose(out, np.cumsum(x.astype(np.float64)),
                                   rtol=1e-3, atol=1e-2)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(-5, 5), min_size=1, max_size=500))
    def test_property_int_exact(xs):
        x = np.asarray(xs, np.int32)
        out = np.asarray(scan(jnp.asarray(x), method="matmul", tile_s=16))
        np.testing.assert_array_equal(out, np.cumsum(x))

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.floats(-10, 10, allow_nan=False, width=32),
                    min_size=2, max_size=300))
    def test_property_exclusive_shift(xs):
        """exclusive scan == inclusive scan shifted right with 0 prepended."""
        x = jnp.asarray(np.asarray(xs, np.float32))
        inc = np.asarray(scan(x, tile_s=16))
        exc = np.asarray(scan(x, exclusive=True, tile_s=16))
        np.testing.assert_allclose(exc[1:], inc[:-1], rtol=1e-5, atol=1e-5)
        assert exc[0] == 0.0

else:

    @pytest.mark.skip(reason="hypothesis not installed — property tests skipped")
    def test_property_suite():
        pass  # visible placeholder so missing hypothesis shows as a skip
