"""Scan-based operators (paper §5): split, compress, radix sort, top-k, top-p,
weighted sampling."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # property tests skip (not error) in minimal environments
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import (compress, radix_sort, split, top_p_sample, topk,
                        weighted_sample)


def test_split_stable_with_indices():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(1000).astype(np.float32)
    f = rng.random(1000) < 0.4
    z, ind, nt = split(jnp.asarray(x), jnp.asarray(f))
    nt = int(nt)
    assert nt == f.sum()
    np.testing.assert_allclose(np.asarray(z)[:nt], x[f])
    np.testing.assert_allclose(np.asarray(z)[nt:], x[~f])
    np.testing.assert_array_equal(np.asarray(ind)[:nt], np.nonzero(f)[0])
    np.testing.assert_array_equal(np.asarray(ind)[nt:], np.nonzero(~f)[0])


def test_split_batched():
    rng = np.random.default_rng(1)
    x = rng.standard_normal((4, 200)).astype(np.float32)
    f = rng.random((4, 200)) < 0.5
    z, ind, nt = split(jnp.asarray(x), jnp.asarray(f))
    for b in range(4):
        n = int(nt[b])
        np.testing.assert_allclose(np.asarray(z)[b, :n], x[b][f[b]])


def test_compress_matches_masked_select():
    rng = np.random.default_rng(2)
    x = rng.standard_normal(517).astype(np.float32)
    m = rng.random(517) < 0.3
    vals, cnt = compress(jnp.asarray(x), jnp.asarray(m))
    np.testing.assert_allclose(np.asarray(vals)[:int(cnt)], x[m])
    assert np.all(np.asarray(vals)[int(cnt):] == 0)


@pytest.mark.parametrize("dtype", [np.float16, np.float32, np.int32, np.int16,
                                   np.uint16, np.int8])
def test_radix_sort_dtypes(dtype):
    rng = np.random.default_rng(3)
    if np.issubdtype(dtype, np.floating):
        x = rng.standard_normal(800).astype(dtype)
    else:
        info = np.iinfo(dtype)
        x = rng.integers(info.min, info.max, 800).astype(dtype)
    v, idx = radix_sort(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(v), np.sort(x, kind="stable"))
    np.testing.assert_array_equal(x[np.asarray(idx)], np.asarray(v))


def test_radix_sort_descending_and_special_values():
    x = np.asarray([0.0, -0.5, 2.5, -3.25, 1.0, 65504.0, -65504.0, 0.125],
                   np.float16)
    vd, _ = radix_sort(jnp.asarray(x), descending=True)
    np.testing.assert_array_equal(np.asarray(vd), np.sort(x)[::-1])


def test_radix_sort_stability():
    """Equal keys keep input order (required by the paper's SplitInd semantics)."""
    x = np.asarray([3, 1, 3, 1, 2, 2, 1], np.int32)
    _, idx = radix_sort(jnp.asarray(x))
    ones = np.asarray(idx)[:3]
    np.testing.assert_array_equal(ones, [1, 3, 6])


def test_topk():
    rng = np.random.default_rng(4)
    x = rng.standard_normal(512).astype(np.float16)
    v, i = topk(jnp.asarray(x), 16)
    np.testing.assert_array_equal(np.asarray(v), np.sort(x)[::-1][:16])
    np.testing.assert_array_equal(x[np.asarray(i)], np.asarray(v))


def test_weighted_sample_distribution():
    w = jnp.asarray([1.0, 0.0, 3.0, 0.0])
    keys = jax.random.split(jax.random.PRNGKey(0), 3000)
    s = np.asarray(jax.vmap(lambda k: weighted_sample(w, k))(keys))
    counts = np.bincount(s, minlength=4)
    assert counts[1] == 0 and counts[3] == 0
    assert abs(counts[2] / 3000 - 0.75) < 0.04


def test_top_p_restricts_to_nucleus():
    # one dominant token: p=0.5 nucleus is exactly {argmax}
    logits = jnp.asarray(np.r_[10.0, np.zeros(63)], jnp.float32)[None, :]
    keys = jax.random.split(jax.random.PRNGKey(1), 50)
    toks = np.asarray(jax.vmap(
        lambda k: top_p_sample(logits, k, p=0.5))(keys))
    assert np.all(toks == 0)


def test_top_p_batched_scan_vs_xla_sort():
    rng = np.random.default_rng(5)
    logits = jnp.asarray(rng.standard_normal((8, 128)) * 2, jnp.float32)
    k = jax.random.PRNGKey(2)
    a = top_p_sample(logits, k, p=0.9, sort_method="radix")
    b = top_p_sample(logits, k, p=0.9, sort_method="xla")
    # same key, same nucleus -> overwhelmingly the same samples (bf16 key ties
    # can reorder within ~1-ulp probability bands)
    assert np.mean(np.asarray(a) == np.asarray(b)) > 0.7


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 1), min_size=1, max_size=300))
    def test_property_split_partition(flags):
        f = np.asarray(flags, bool)
        x = np.arange(len(f), dtype=np.float32)
        z, ind, nt = split(jnp.asarray(x), jnp.asarray(f))
        nt = int(nt)
        assert nt == f.sum()
        # output is a permutation that is stable within each class
        np.testing.assert_allclose(np.sort(np.asarray(z)), x)
        np.testing.assert_array_equal(np.asarray(z)[:nt], x[f])

    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.floats(-100, 100, allow_nan=False, width=16),
                    min_size=1, max_size=200))
    def test_property_radix_sort(xs):
        x = np.asarray(xs, np.float16)
        v, _ = radix_sort(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(v), np.sort(x, kind="stable"))

else:

    @pytest.mark.skip(reason="hypothesis not installed — property tests skipped")
    def test_property_suite():
        pass  # visible placeholder so missing hypothesis shows as a skip
