"""Precision axis (``precision="compensated"``): resolution, split math, ulp gates.

The contract under test (docs/architecture.md dispatch rule 9 +
:mod:`repro.analysis.ulp`):

* resolution is pre-trace with the chain override > ``REPRO_SCAN_PRECISION``
  env > call-site argument; explicit ``method="vector"`` + explicit
  non-default precision raises; auto/override/env landing on vector silently
  degrades to ``"highest"`` (the vector path *is* the fp32 reference);
* ``precision="highest"`` traces byte-identically to the pre-precision code;
* the Ozaki split is exact (``x == ldexp(hi + ldexp(lo, -SPLIT_SHIFT), e)``
  whenever the per-slice dynamic range fits the ~22-bit window);
* measured max ulp at the conditioning scale stays under
  ``ULP_COEFF[precision] * sqrt(n)`` for scan / linear_scan / segment_scan on
  every matmul-engine method — including subnormal, near-fp16-overflow and
  non-finite inputs;
* integer scans are bit-exact under every precision.

Sweeps run twice: a seeded deterministic sweep that always runs, and a
hypothesis property sweep that activates when hypothesis is installed (the
container gates it; profiles live in ``conftest.py``).
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import ulp
from repro.core import precision as prec
from repro.core.linrec import cumprod, linear_scan
from repro.core.precision import (
    ENV_VAR, PRECISIONS, SPLIT_SHIFT, normalize_exponents, pdot,
    precision_override, resolve_precision, split_f16,
)
from repro.core.scan import cumsum, scan
from repro.core.segmented import segment_scan
from repro.core.ssd import ssd_scan, ssd_scan_ref
from ulp_oracle import (
    assert_within_bound, linrec_case, scan_case, segment_scan_case,
)

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
    HAS_HYPOTHESIS = True
except ImportError:  # container without hypothesis: the seeded sweeps cover
    HAS_HYPOTHESIS = False

ENGINE_METHODS = ("matmul", "kernel", "blocked")


@pytest.fixture(autouse=True)
def _no_env_precision(monkeypatch):
    monkeypatch.delenv(ENV_VAR, raising=False)


# ---------------------------------------------------------------------------
# resolution: override > env > argument; the vector-path rules
# ---------------------------------------------------------------------------


def test_resolution_chain(monkeypatch):
    assert resolve_precision("compensated", method="matmul") == "compensated"
    monkeypatch.setenv(ENV_VAR, "fast")
    assert resolve_precision("compensated", method="matmul") == "fast"
    with precision_override("compensated"):
        assert resolve_precision("highest", method="kernel") == "compensated"
    monkeypatch.setenv(ENV_VAR, "nonsense")
    with pytest.raises(ValueError, match="nonsense"):
        resolve_precision("highest", method="matmul")


def test_unknown_precision_rejected():
    with pytest.raises(ValueError, match="unknown precision"):
        resolve_precision("double", method="matmul")
    with pytest.raises(ValueError):
        with precision_override("double"):
            pass


def test_explicit_vector_with_precision_raises():
    x = jnp.ones(64, jnp.float32)
    for fn in (lambda: scan(x, method="vector", precision="compensated"),
               lambda: cumsum(x, method="vector", precision="fast"),
               lambda: linear_scan(x, x, method="vector",
                                   precision="compensated"),
               lambda: segment_scan(x, jnp.asarray([0, 64]), method="vector",
                                    precision="compensated")):
        with pytest.raises(ValueError, match="matmul-engine"):
            fn()


def test_vector_with_default_precision_fine():
    x = jnp.ones(64, jnp.float32)
    assert scan(x, method="vector", precision="highest").shape == (64,)


def test_auto_landing_on_vector_degrades_silently():
    # n=64 fp32 resolves to vector on the committed cpu table
    from repro.core.autotune import resolve_method
    x = jnp.ones(64, jnp.float32)
    if resolve_method("scan", 64, jnp.float32) == "vector":
        out = scan(x, method="auto", precision="compensated")
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(scan(x, method="vector")))


def test_override_degrades_on_vector_path():
    x = jnp.arange(32, dtype=jnp.float32)
    with precision_override("fast"):
        out = scan(x, method="vector")  # never touches the engine
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(scan(x, method="vector")))


def test_env_precision_changes_resolution_pre_trace(monkeypatch):
    x = jnp.asarray(np.random.default_rng(0).standard_normal(256), jnp.float32)
    monkeypatch.setenv(ENV_VAR, "fast")
    got_env = scan(x, method="matmul", tile_s=16)
    monkeypatch.delenv(ENV_VAR)
    got_arg = scan(x, method="matmul", tile_s=16, precision="fast")
    np.testing.assert_array_equal(np.asarray(got_env), np.asarray(got_arg))


# ---------------------------------------------------------------------------
# highest is the identity: pdot traces exactly like jnp.matmul
# ---------------------------------------------------------------------------


def _jaxpr(fn, *args):
    return re.sub(r"0x[0-9a-f]+", "0x", str(jax.make_jaxpr(fn)(*args)))


def test_pdot_highest_is_plain_matmul():
    a = jnp.ones((4, 8), jnp.float32)
    b = jnp.ones((8, 4), jnp.float32)
    for exact in ("none", "left", "right"):
        assert _jaxpr(lambda u, v: pdot(u, v, acc=jnp.float32,
                                        precision="highest", exact=exact),
                      a, b) == \
            _jaxpr(lambda u, v: jnp.matmul(
                u, v, preferred_element_type=jnp.float32), a, b)


def test_pdot_non_f32_data_falls_through():
    a = jnp.ones((4, 8), jnp.int8)
    b = jnp.ones((8, 4), jnp.int8)
    for p in PRECISIONS:
        out = pdot(a, b, acc=jnp.int32, precision=p)
        assert out.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(out), 8)


# ---------------------------------------------------------------------------
# the split itself: exactness and exponent handling
# ---------------------------------------------------------------------------


def _reconstruct(hi, lo, e):
    return np.ldexp(np.asarray(hi, np.float32)
                    + np.ldexp(np.asarray(lo, np.float32), -SPLIT_SHIFT),
                    np.asarray(e))


def _assert_split_window(x):
    """Split/reconstruct ``x``: error < 2^-22 of the slice max, exactly 0 for
    values whose mantissa fits 22 bits."""
    hi, lo, e = split_f16(jnp.asarray(x, jnp.float32), axis=-1)
    recon = _reconstruct(hi, lo, e)
    xs = np.asarray(x, np.float64)
    slice_max = np.max(np.abs(xs), axis=-1, keepdims=True)
    err = np.abs(recon.astype(np.float64) - xs)
    assert np.all(err <= slice_max * 2.0 ** -22 + 0.0), np.max(err / slice_max)


def test_split_exact_for_22bit_mantissas():
    rng = np.random.default_rng(1)
    # 22-bit integers scaled by powers of two: exactly representable by hi+lo
    ints = rng.integers(-(1 << 21), 1 << 21, (4, 64)).astype(np.float64)
    x = ints * 2.0 ** rng.integers(-30, 30, (4, 1))
    hi, lo, e = split_f16(jnp.asarray(x, jnp.float32), axis=-1)
    np.testing.assert_array_equal(_reconstruct(hi, lo, e),
                                  np.asarray(x, np.float32))


def test_split_window_random_and_extreme_rows():
    rng = np.random.default_rng(2)
    sgn = rng.choice([-1.0, 1.0], (4, 32))
    mag = 0.5 + np.abs(rng.standard_normal((4, 32)))   # normal-range mantissas
    _assert_split_window(rng.standard_normal((8, 32)))
    _assert_split_window(sgn * mag * 1e30)             # near fp32 overflow
    _assert_split_window(sgn * mag * 1e-33)            # near the normal floor


def test_split_flushes_subnormals_to_zero():
    # XLA flushes subnormal operands in the scaling multiplies themselves, so
    # subnormal inputs become exact zeros — the documented backend floor
    # shared by every precision (no nan/inf, no garbage).
    x = jnp.asarray([[1e-40, -1e-39, 0.0, 1e-44]], jnp.float32)
    hi, lo, _ = split_f16(x, axis=-1)
    np.testing.assert_array_equal(np.asarray(hi, np.float32), 0.0)
    np.testing.assert_array_equal(np.asarray(lo, np.float32), 0.0)


def test_split_propagates_nonfinite_and_zero_rows():
    x = jnp.asarray([[1.0, np.inf, -3.0, np.nan],
                     [0.0, 0.0, 0.0, 0.0]], jnp.float32)
    hi, lo, e = split_f16(x, axis=-1)
    h = np.asarray(hi, np.float32)
    assert np.isposinf(h[0, 1]) and np.isnan(h[0, 3])
    assert np.all(np.asarray(lo, np.float32)[0, [1, 3]] == 0)
    np.testing.assert_array_equal(h[1], 0)
    np.testing.assert_array_equal(np.asarray(lo)[1], 0)


def test_normalize_exponents_exact():
    rng = np.random.default_rng(3)
    a = rng.standard_normal(256) * 10.0 ** rng.integers(-30, 30, 256)
    m, e = normalize_exponents(jnp.asarray(a, jnp.float32), jnp.float32)
    m = np.asarray(m, np.float64)
    nz = np.asarray(a, np.float32) != 0
    assert np.all((np.abs(m[nz]) >= prec._SQRT_HALF - 1e-9)
                  & (np.abs(m[nz]) < np.sqrt(2) + 1e-9))
    np.testing.assert_array_equal(
        np.ldexp(m, np.asarray(e)).astype(np.float32), np.asarray(a, np.float32))


# ---------------------------------------------------------------------------
# ulp gates: the documented bound across op x method x precision x n
# ---------------------------------------------------------------------------


def _cases(rng, n):
    x = rng.standard_normal(n) * np.exp(rng.standard_normal(n))
    a = np.exp(-np.abs(rng.standard_normal(n)))          # decays in (0, 1]
    b = rng.standard_normal(n)
    k = max(1, n // 7)
    starts = np.sort(rng.choice(n, size=k, replace=False))
    starts[0] = 0
    offsets = np.concatenate([starts, [n]]).astype(np.int32)
    return x, a, b, offsets


@pytest.mark.parametrize("method", ENGINE_METHODS)
@pytest.mark.parametrize("precision", PRECISIONS)
@pytest.mark.parametrize("n", [5, 97, 600])
def test_ulp_bound_seeded_sweep(method, precision, n):
    rng = np.random.default_rng(n * 7 + len(method))
    x, a, b, offsets = _cases(rng, n)
    for rep in (scan_case(x, method=method, precision=precision, tile_s=8),
                linrec_case(a, b, method=method, precision=precision,
                            tile_s=8),
                segment_scan_case(x, offsets, method=method,
                                  precision=precision, tile_s=8)):
        assert_within_bound(rep)


@pytest.mark.parametrize("method", ENGINE_METHODS)
def test_compensated_tracks_fp32_vector(method):
    # the recovery claim head-on: compensated within a small ulp distance of
    # the fp32 vector reference itself, at the vector result's own scale
    rng = np.random.default_rng(11)
    x = rng.standard_normal(512)
    ref = np.asarray(scan(jnp.asarray(x, jnp.float32), method="vector"),
                     np.float64)
    got = scan(jnp.asarray(x, jnp.float32), method=method, tile_s=8,
               precision="compensated")
    mu = ulp.max_ulp(np.asarray(got), ref, ulp.scan_scale(x))
    assert mu <= ulp.ulp_bound("compensated", 512), mu


def test_subnormal_inputs_flush_deterministically():
    rng = np.random.default_rng(5)
    # every input a fp32 subnormal: XLA flushes them in matmul and in the
    # split's scaling multiplies alike, so all engine paths produce exact
    # zeros — deterministic, finite, and identical across precisions (the
    # documented proviso: the ulp bounds assume normal-range inputs).
    x = (rng.standard_normal(256) * 1e-40).astype(np.float32).astype(np.float64)
    assert np.all(np.abs(x[x != 0]) < np.finfo(np.float32).tiny)
    for p in ("highest", "compensated"):
        got = np.asarray(scan(jnp.asarray(x, jnp.float32), method="kernel",
                              tile_s=8, precision=p))
        assert np.isfinite(got).all()
        np.testing.assert_array_equal(got, 0.0)


def test_near_tiny_normal_inputs_within_bound():
    rng = np.random.default_rng(9)
    # normal-range values just above the subnormal floor: the exact
    # power-of-two slice scaling makes the bound exponent-independent
    x = (0.5 + np.abs(rng.standard_normal(256))) * 1e-35 \
        * rng.choice([-1.0, 1.0], 256)
    for p in ("highest", "compensated"):
        assert_within_bound(scan_case(x, method="kernel", precision=p,
                                      tile_s=8))


def test_near_fp16_overflow_within_bound():
    rng = np.random.default_rng(6)
    # far outside fp16 range (max ~65504): the exact scaling brings each
    # slice back into range, so the bound must hold unchanged
    x = rng.standard_normal(256) * 1e30
    for p in ("highest", "compensated"):
        assert_within_bound(scan_case(x, method="blocked", precision=p,
                                      tile_s=8))


def test_extreme_intra_slice_range_bounded_at_final_scale():
    # elements below ~2^-35 of their slice max are lost by the split (below
    # fp32 significance at the slice scale); the documented guarantee there
    # is at the end-of-scan conditioning scale, not per element
    x = np.ones(64)
    x[37] = 1e30
    got = scan(jnp.asarray(x, jnp.float32), method="kernel", tile_s=8,
               precision="compensated")
    ref, scale = ulp.scan_ref(x), ulp.scan_scale(x)
    mu = ulp.max_ulp(np.asarray(got), ref, scale[-1:])
    assert mu <= ulp.ulp_bound("compensated", 64), mu


@pytest.mark.parametrize("precision", ("compensated", "fast"))
@pytest.mark.parametrize("method", ENGINE_METHODS)
def test_nonfinite_propagation_matches_engine_reference(method, precision):
    # the contract: non-finites ride the split's high part unchanged, so
    # inf/nan propagate exactly as through the fp32 engine ("highest") on the
    # SAME method — not as the vector cumsum, because any matmul formulation
    # spreads nan within a tile via inf * 0 against the triangular zeros.
    x = np.ones(48)
    x[10], x[30] = np.inf, np.nan
    xj = jnp.asarray(x, jnp.float32)
    got = np.asarray(scan(xj, method=method, tile_s=4, precision=precision),
                     np.float64)
    ref = np.asarray(scan(xj, method=method, tile_s=4, precision="highest"),
                     np.float64)
    np.testing.assert_array_equal(np.isnan(got), np.isnan(ref))
    fin = np.isfinite(ref)
    np.testing.assert_array_equal(got[~fin & ~np.isnan(ref)],
                                  ref[~fin & ~np.isnan(ref)])
    # every element at/after the nan is non-finite on every path
    assert not np.isfinite(got[30:]).any()


@pytest.mark.parametrize("precision", PRECISIONS)
def test_integer_scans_bit_exact(precision):
    rng = np.random.default_rng(7)
    xi = rng.integers(-100, 100, 300).astype(np.int32)
    ref = np.cumsum(xi)
    for method in ENGINE_METHODS:
        got = scan(jnp.asarray(xi), method=method, tile_s=8,
                   precision=precision)
        np.testing.assert_array_equal(np.asarray(got), ref)
    off = np.asarray([0, 150, 300], np.int32)
    seg = segment_scan(jnp.asarray(xi), jnp.asarray(off), method="kernel",
                       tile_s=8, precision=precision)
    assert np.array_equal(np.asarray(seg)[:150], np.cumsum(xi[:150]))


def test_cumprod_and_ssd_accept_precision():
    rng = np.random.default_rng(8)
    a = np.exp(rng.standard_normal(128) * 0.1)
    got = cumprod(jnp.asarray(a, jnp.float32), method="matmul", tile_s=8,
                  precision="compensated")
    ref = np.cumprod(a)
    np.testing.assert_allclose(np.asarray(got, np.float64), ref, rtol=1e-5)
    x = jnp.asarray(rng.standard_normal((1, 32, 2, 4)), jnp.float32)
    al = jnp.asarray(-np.abs(rng.standard_normal((1, 32, 2))), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((1, 32, 2, 3)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((1, 32, 2, 3)), jnp.float32)
    y = ssd_scan(x, al, bm, cm, chunk=16, scan_method="matmul",
                 precision="compensated")
    np.testing.assert_allclose(np.asarray(y), np.asarray(
        ssd_scan_ref(x, al, bm, cm)), atol=1e-4)


def test_linrec_grad_runs_under_compensated():
    a = jnp.full((64,), 0.9, jnp.float32)
    b = jnp.ones((64,), jnp.float32)
    g = jax.grad(lambda u, v: jnp.sum(linear_scan(
        u, v, method="matmul", tile_s=8, precision="compensated")))(a, b)
    gref = jax.grad(lambda u, v: jnp.sum(linear_scan(
        u, v, method="matmul", tile_s=8)))(a, b)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gref),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# hypothesis property sweeps (gated: activate where hypothesis is installed)
# ---------------------------------------------------------------------------


if HAS_HYPOTHESIS:
    finite_f32 = st.floats(min_value=-1e30, max_value=1e30, width=32,
                           allow_nan=False, allow_infinity=False,
                           allow_subnormal=True)

    def _flush(x):
        # the documented backend floor: subnormal inputs flush to exact zero
        # on every engine path, so the fp64 oracle is stated on FTZ(x)
        return np.where(np.abs(x) < np.finfo(np.float32).tiny, 0.0,
                        np.asarray(x, np.float64))

    @given(x=hnp.arrays(np.float32, st.integers(1, 300), elements=finite_f32),
           method=st.sampled_from(ENGINE_METHODS),
           precision=st.sampled_from(PRECISIONS))
    @settings(deadline=None)
    def test_hyp_scan_final_scale_bound(x, method, precision):
        got = scan(jnp.asarray(x), method=method, tile_s=8,
                   precision=precision)
        xf = _flush(x)
        mu = ulp.max_ulp(np.asarray(got), ulp.scan_ref(xf),
                         ulp.scan_scale(xf)[-1:])
        assert mu <= ulp.ulp_bound(precision, x.shape[0]), mu

    @given(x=hnp.arrays(np.float32, st.integers(1, 200),
                        elements=st.floats(-100, 100, width=32)),
           precision=st.sampled_from(PRECISIONS))
    @settings(deadline=None)
    def test_hyp_moderate_range_per_element_bound(x, precision):
        assert_within_bound(scan_case(_flush(x), method="kernel",
                                      precision=precision, tile_s=8))

    @given(hi=hnp.arrays(np.float32, 64,
                         elements=st.floats(-1e30, 1e30, width=32,
                                            allow_subnormal=True)))
    @settings(deadline=None)
    def test_hyp_split_window(hi):
        _assert_split_window(hi[None, :])
