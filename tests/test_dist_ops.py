"""Distributed operator family (`repro.core.dist_ops`) on 8 host devices.

Every test spawns a fresh interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (device count is
locked at jax init).  The contract under test is docs/distributed.md:

* **parity** — every ``dist_*`` operator equals its single-device sibling on
  the gathered input: bit-identical for sorts / top-k / integer recurrences /
  segmented scans, rule-2 float convention for fp recurrences, and identical
  sampled tokens for top-p across seeds on the test matrix;
* **collective counts** — the traced jaxpr stages exactly the collectives the
  traffic model of ``repro.analysis.collectives.modeled_dist_traffic``
  charges for (one ``all_to_all`` + one histogram ``all_gather`` per radix
  pass; one carry ``all_gather`` for linrec/segscan);
* **engine wiring** — ``ContinuousEngine(sampler="topp_sharded")`` on a
  model-axis mesh preserves the exact-stream contract vs a solo
  ``ServeEngine`` with the same sampler and per-request key.

Compiles on the CPU test backend are expensive (~20-35 s per distributed
operator), so the matrix is deliberately frugal: shard counts {2, 4, 8} and
the four methods are spread across cases rather than fully crossed, and
repeated calls reuse one jitted function.
"""
import os
import subprocess
import sys
import textwrap

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_PRELUDE = """
    import numpy as np, jax, jax.numpy as jnp
    from repro.utils.compat import make_mesh
    rng = np.random.default_rng(0)
"""


def run_sub(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c",
                        textwrap.dedent(_PRELUDE) + textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_dist_sort_topk_parity_stability():
    """Sort/topk == single-device sibling bitwise: values AND permutation.

    Duplicate uint8 keys pin stability (the shard-major bucket exchange must
    preserve arrival order); bf16 descending on the kernel method covers the
    complement-before-widen encoding; int8 top-k at a ragged length covers
    the max-fill padding path; D=8 covers one-element-per-shard-ish splits.
    """
    run_sub("""
        from repro.core import dist_radix_sort, dist_topk
        from repro.core.primitives import radix_sort
        mesh2 = make_mesh((2,), ("data",))
        mesh4 = make_mesh((4,), ("data",))
        mesh8 = make_mesh((8,), ("data",))
        x = jnp.asarray(rng.integers(0, 4, size=(2, 14)), jnp.uint8)
        v0, i0 = radix_sort(x, method="matmul", tile_s=8, bits_per_pass=8)
        v1, i1 = dist_radix_sort(x, mesh2, "data", method="matmul", tile_s=8,
                                 bits_per_pass=8)
        assert np.array_equal(v0, v1) and np.array_equal(i0, i1), "u8 D=2"
        xr = jnp.asarray(rng.integers(0, 200, size=(19,)), jnp.uint8)
        v0, i0 = radix_sort(xr, method="matmul", tile_s=8, bits_per_pass=8)
        v1, i1 = dist_radix_sort(xr, mesh8, "data", method="matmul", tile_s=8,
                                 bits_per_pass=8)
        assert np.array_equal(v0, v1) and np.array_equal(i0, i1), "u8 D=8"
        xb = jnp.asarray(rng.normal(size=(2, 16)), jnp.bfloat16)
        v0, i0 = radix_sort(xb, descending=True, method="kernel", tile_s=8,
                            bits_per_pass=8)
        v1, i1 = dist_radix_sort(xb, mesh4, "data", descending=True,
                                 method="kernel", tile_s=8, bits_per_pass=8)
        assert np.array_equal(np.asarray(v0, np.float32),
                              np.asarray(v1, np.float32)) \\
            and np.array_equal(i0, i1), "bf16 desc kernel D=4"
        xi = jnp.asarray(rng.integers(-4, 4, size=(13,)), jnp.int8)
        v0, i0 = radix_sort(xi, descending=True, method="vector", tile_s=8,
                            bits_per_pass=8)
        v1, i1 = dist_topk(xi, 13, mesh4, "data", method="vector", tile_s=8,
                           bits_per_pass=8)
        assert np.array_equal(v0, v1) and np.array_equal(i0, i1), "topk D=4"
        print("DIST-SORT-OK")
        """)


def test_dist_linrec_segment_scan_parity():
    """Affine-carry recurrences and segmented scans vs the local siblings.

    Integer payloads must be bit-identical (exact affine carries); fp32 to
    rounding tolerance (the carry fold reorders additions); `initial=` seeds
    shard 0's carry; segmented offsets sweep empty / full / aligned segments
    through ONE jitted function (the offsets are data, not trace constants).
    """
    run_sub("""
        from repro.core import dist_linear_scan, dist_segment_scan
        from repro.core.linrec import linear_scan
        from repro.core.segmented import segment_scan
        mesh2 = make_mesh((2,), ("data",))
        mesh4 = make_mesh((4,), ("data",))
        mesh8 = make_mesh((8,), ("data",))
        a = jnp.asarray(rng.uniform(0.8, 1.2, size=(2, 13)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(2, 13)), jnp.float32)
        y0 = linear_scan(a, b, exclusive=True, method="kernel", tile_s=8)
        y1 = dist_linear_scan(a, b, mesh4, "data", exclusive=True,
                              method="kernel", tile_s=8)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-5, atol=2e-5)
        ai = jnp.ones((2, 16), jnp.int32)
        bi = jnp.asarray(rng.integers(0, 5, size=(2, 16)), jnp.int32)
        y0 = linear_scan(ai, bi, method="matmul", tile_s=8)
        y1 = dist_linear_scan(ai, bi, mesh8, "data", method="matmul", tile_s=8)
        assert np.array_equal(y0, y1), "int exact D=8"
        y0 = linear_scan(a[0], b[0], initial=3.0, method="matmul", tile_s=8)
        y1 = dist_linear_scan(a[0], b[0], mesh2, "data", initial=3.0,
                              method="matmul", tile_s=8)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-5, atol=2e-5)
        xs = jnp.asarray(rng.integers(-5, 5, size=(2, 16)), jnp.int8)
        f0 = jax.jit(lambda v, o: segment_scan(v, o, method="matmul",
                                               tile_s=8))
        f1 = jax.jit(lambda v, o: dist_segment_scan(v, o, mesh4, "data",
                                                    method="matmul", tile_s=8))
        for offs in ([0, 5, 11, 16], [0, 16], [0, 0, 7, 7, 7, 16, 16],
                     [0, 4, 8, 12, 16]):
            o = jnp.asarray(offs, jnp.int32)
            assert np.array_equal(f0(xs, o), f1(xs, o)), offs
        # integer-valued fp32: sums stay exactly representable, so rule 2
        # promises bit-identity even though the carry association differs
        xf = jnp.asarray(rng.integers(-4, 5, size=(15,)), jnp.float32)
        o = jnp.asarray([0, 6, 15], jnp.int32)
        y0 = segment_scan(xf, o, exclusive=True, method="blocked", tile_s=4,
                          block_tiles=2)
        y1 = dist_segment_scan(xf, o, mesh2, "data", exclusive=True,
                               method="blocked", tile_s=4, block_tiles=2)
        assert np.array_equal(y0, y1), "segscan blocked excl D=2"
        print("DIST-LINREC-SEGSCAN-OK")
        """)


def test_dist_top_p_parity_and_edge_policies():
    """Sharded top-p == single-device sampler token-for-token across seeds.

    Same bf16 sort keys, same uniform consumption (one draw per row), same
    llama3 cut — the sharded softmax reorders the denominator sum, so
    docs/distributed.md documents the fp contract as documented-ulp on the
    probabilities with token flips only at nucleus-threshold ties; across
    this matrix the tokens are identical.  Temperature, the temperature=0
    greedy limit, and nonfinite="sanitize" row rewrites ride along.
    """
    run_sub("""
        from repro.core import dist_top_p_sample
        from repro.core.primitives import top_p_sample
        logits = jnp.asarray(rng.normal(size=(4, 33)) * 3, jnp.float32)
        meshm = make_mesh((2,), ("model",))
        g0 = jax.jit(lambda lg, k: top_p_sample(lg, k, p=0.8, method="matmul",
                                                tile_s=8))
        g1 = jax.jit(lambda lg, k: dist_top_p_sample(lg, k, meshm, "model",
                                                     p=0.8, method="matmul",
                                                     tile_s=8))
        for seed in range(8):
            k = jax.random.PRNGKey(seed)
            assert np.array_equal(g0(logits, k), g1(logits, k)), seed
        k = jax.random.PRNGKey(7)
        t0 = top_p_sample(logits, k, p=0.9, temperature=0.7, method="matmul",
                          tile_s=8)
        t1 = dist_top_p_sample(logits, k, meshm, "model", p=0.9,
                               temperature=0.7, method="matmul", tile_s=8)
        assert np.array_equal(t0, t1), "temperature"
        t1 = dist_top_p_sample(logits, k, meshm, "model", temperature=0.0)
        assert np.array_equal(t1, jnp.argmax(logits, -1)), "greedy limit"
        bad = logits.at[0].set(jnp.nan)
        t0 = top_p_sample(bad, k, method="matmul", tile_s=8,
                          nonfinite="sanitize")
        t1 = dist_top_p_sample(bad, k, meshm, "model", method="matmul",
                               tile_s=8, nonfinite="sanitize")
        assert np.asarray(t0)[0] == np.asarray(t1)[0], "sanitize row"
        print("DIST-TOPP-OK")
        """)


def test_dist_top_p_kernel_method_and_batched_u():
    """Kernel-method passes inside shard_map + the engines' u= batching.

    The batched path is what ``ContinuousEngine._sample_rows`` runs: one
    distributed call on (B, V) logits with per-row pre-drawn uniforms must
    equal B solo per-row samples with the rows' keys (``uniform(k, (1,))``
    and ``uniform(k, (1, 1))`` consume identical bits from the same key).
    """
    run_sub("""
        from repro.core import dist_top_p_sample
        from repro.core.primitives import top_p_sample
        logits = jnp.asarray(rng.normal(size=(4, 33)) * 3, jnp.float32)
        mesh4 = make_mesh((4,), ("model",))
        g0 = jax.jit(lambda lg, k: top_p_sample(lg, k, p=0.8, method="kernel",
                                                tile_s=8))
        g1 = jax.jit(lambda lg, k: dist_top_p_sample(lg, k, mesh4, "model",
                                                     p=0.8, method="kernel",
                                                     tile_s=8))
        for seed in range(3):
            k = jax.random.PRNGKey(seed)
            assert np.array_equal(g0(logits, k), g1(logits, k)), seed
        meshm = make_mesh((2,), ("model",))
        keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(4, dtype=jnp.uint32))
        u = jax.vmap(lambda kk: jax.random.uniform(kk, (1,), jnp.float32))(keys)
        t1 = dist_top_p_sample(logits, None, meshm, "model", p=0.8,
                               method="matmul", tile_s=8, u=u)
        solo = jax.jit(lambda lg, kk: top_p_sample(lg[None], kk, p=0.8,
                                                   method="matmul",
                                                   tile_s=8)[0])
        t0 = jnp.stack([solo(logits[r], keys[r]) for r in range(4)])
        assert np.array_equal(t0, t1), "batched u vs per-row solo"
        print("DIST-TOPP-KERNEL-OK")
        """)


def test_collective_count_guards():
    """Trace-only: staged collectives match the traffic model's counts.

    Exactly one ``all_to_all`` + one histogram ``all_gather`` per radix pass;
    P passes + two block-sum gathers + one shard-threshold gather and four
    all-reduces for top-p; one carry ``all_gather`` for linrec; and the
    1-device short-circuits stage no collectives at all (fix: `mcscan` used
    to stage shard_map even on a 1-device mesh).
    """
    run_sub("""
        import re
        from repro.core import (dist_linear_scan, dist_radix_sort,
                                dist_top_p_sample)
        from repro.core.distributed import mcscan
        mesh1 = make_mesh((1,), ("data",))
        mesh4 = make_mesh((4,), ("data",))

        def eqns(jx, prim):
            # count equations, not substrings: the all_gather_dimension=
            # param would double a bare "all_gather" count
            return len(re.findall(re.escape(prim) + r"\\[", str(jx)))

        xi32 = jnp.asarray(rng.integers(-100, 100, size=(32,)), jnp.int32)
        jx = jax.make_jaxpr(lambda v: dist_radix_sort(
            v, mesh4, "data", method="matmul", tile_s=8,
            bits_per_pass=8))(xi32)
        assert eqns(jx, "all_to_all") == 4 and eqns(jx, "all_gather") == 4, \\
            "int32 k=8: 4 passes -> 4 exchanges + 4 histogram gathers"
        jx1 = str(jax.make_jaxpr(lambda v: dist_radix_sort(
            v, mesh1, "data", method="matmul", tile_s=8))(xi32))
        assert "all_to_all" not in jx1 and "all_gather" not in jx1
        jx2 = str(jax.make_jaxpr(lambda v: mcscan(v[None], mesh1, "data",
                                                  method="matmul",
                                                  tile_s=8))(xi32))
        assert "all_gather" not in jx2 and "shard_map" not in jx2
        a = jnp.asarray(rng.uniform(0.8, 1.2, size=(13,)), jnp.float32)
        jx3 = jax.make_jaxpr(lambda v: dist_linear_scan(
            v, v, mesh4, "data", method="matmul", tile_s=8))(a)
        assert eqns(jx3, "all_gather") == 1 \\
            and "all_to_all" not in str(jx3)
        lg = jnp.asarray(rng.normal(size=(2, 33)), jnp.float32)
        jx4 = jax.make_jaxpr(lambda v, k: dist_top_p_sample(
            v, k, mesh4, "data", p=0.8, method="matmul", tile_s=8,
            bits_per_pass=4))(lg, jax.random.PRNGKey(0))
        counts = {p: eqns(jx4, p)
                  for p in ("all_to_all", "all_gather", "psum", "pmax")}
        assert counts == {"all_to_all": 4, "all_gather": 7, "psum": 3,
                          "pmax": 1}, counts
        print("DIST-COUNTS-OK")
        """)


def test_measured_traffic_matches_model_linrec():
    """HLO-measured collective traffic == the closed form (cheapest op).

    The full four-op measured-vs-modeled gate runs in ``benchmarks/run.py
    dist``; here the cheapest compile pins the contract in the test suite so
    a lowering change that splits or fuses the carry all-gather fails fast.
    """
    run_sub("""
        from repro.analysis.collectives import (measure_collectives,
                                                modeled_dist_traffic)
        from repro.core import dist_linear_scan
        mesh8 = make_mesh((8,), ("data",))
        a = jnp.asarray(rng.uniform(0.8, 1.2, size=(2, 256)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(2, 256)), jnp.float32)
        meas = measure_collectives(
            lambda u, v: dist_linear_scan(u, v, mesh8, "data",
                                          method="matmul", tile_s=32), a, b)
        mod = modeled_dist_traffic("dist_linear_scan", d=8, n=256, batch=2,
                                   itemsize=4)
        assert meas["collective_count"] == mod["collective_count"], \\
            (meas, mod)
        assert meas["operand_bytes"] == mod["operand_bytes"], (meas, mod)
        print("DIST-TRAFFIC-OK")
        """)


def test_continuous_engine_topp_sharded_stream_parity():
    """`ContinuousEngine(sampler="topp_sharded")` on a model-axis mesh emits
    token streams exactly equal to solo `ServeEngine` runs per request."""
    run_sub("""
        from repro.models.model import build_model, get_config
        from repro.serving.engine import ServeEngine
        from repro.serving.scheduler import ContinuousEngine, Request
        cfg = get_config("llama3-8b", smoke=True)
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        mesh = make_mesh((2,), ("model",))
        eng = ContinuousEngine(cfg, params, mesh=mesh, max_batch=2,
                               page_size=8, n_pages=9, max_len=24,
                               sampler="topp_sharded", top_p=0.9,
                               tick_tokens=4)
        reqs = [Request(rid=f"r{i}", tokens=np.asarray(t, np.int32),
                        max_new_tokens=n,
                        key=np.asarray(jax.random.PRNGKey(60 + i)),
                        eos_id=None, arrival_step=i)
                for i, (t, n) in enumerate(
                    [(rng.integers(0, cfg.vocab_size, 4), 5),
                     (rng.integers(0, cfg.vocab_size, 6), 4)])]
        res = eng.run(reqs)
        solo = ServeEngine(cfg, params, mesh=mesh, max_len=eng.n_blocks * 8,
                           sampler="topp_sharded", top_p=0.9)
        for r in reqs:
            ref = np.asarray(solo.generate(
                {"tokens": jnp.asarray(r.tokens)[None]}, r.max_new_tokens,
                jnp.asarray(r.key)))[0]
            np.testing.assert_array_equal(res["streams"][r.rid], ref,
                                          err_msg=r.rid)
        print("DIST-ENGINE-OK")
        """)
