"""Continuous-batching subsystem: allocator, paged-vs-dense parity, the
scheduler state machine, and the exact-stream contract vs solo ServeEngine."""
import functools

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import guards
from repro.models.model import build_model, get_config
from repro.serving import paged_kv
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import (ContinuousEngine, Request,
                                     count_while_loops, poisson_trace)

PS = 8  # page size used throughout


@functools.lru_cache(maxsize=None)
def _cfg_params(name="llama3-8b"):
    cfg = get_config(name, smoke=True)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _engine(sampler="greedy", **kw):
    cfg, params = _cfg_params()
    kw.setdefault("max_batch", 2)
    kw.setdefault("page_size", PS)
    kw.setdefault("n_pages", 9)
    kw.setdefault("max_len", 24)
    kw.setdefault("tick_tokens", 4)
    return ContinuousEngine(cfg, params, sampler=sampler, top_p=0.9, **kw)


def _req(rid, tokens, n, seed, eos_id=None, arrival=0):
    return Request(rid=rid, tokens=np.asarray(tokens, np.int32),
                   max_new_tokens=n, key=np.asarray(jax.random.PRNGKey(seed)),
                   eos_id=eos_id, arrival_step=arrival)


# ---------------------------------------------------------------------------
# page allocator (free-list via the paper's compress)
# ---------------------------------------------------------------------------


def test_allocator_lowest_free_first_and_reuse():
    al = paged_kv.PageAllocator(8)          # capacity 7, page 0 reserved
    a = al.alloc(3)
    np.testing.assert_array_equal(a, [1, 2, 3])   # never hands out page 0
    b = al.alloc(4)
    np.testing.assert_array_equal(b, [4, 5, 6, 7])
    assert al.alloc(1) is None and al.in_use == 7 == al.peak_in_use
    al.release(a)
    c = al.alloc(2)                         # freed pages come back, lowest id
    np.testing.assert_array_equal(c, [1, 2])


def test_allocator_rejects_double_free_and_bad_ids():
    al = paged_kv.PageAllocator(4)
    ids = al.alloc(2)
    al.release(ids)
    with pytest.raises(ValueError, match="double free"):
        al.release(ids)
    with pytest.raises(ValueError, match="outside"):
        al.release([0])                     # scratch page is not releasable
    with pytest.raises(ValueError):
        paged_kv.PageAllocator(1)           # nothing left after the scratch


# ---------------------------------------------------------------------------
# paged layout parity (rule 11): the gathered view IS the dense cache
# ---------------------------------------------------------------------------


def test_insert_then_gather_matches_dense_prefill_cache():
    cfg, params = _cfg_params()
    model = build_model(cfg)
    nblk = 3
    caches = paged_kv.build_paged_caches(model, 2, 9, PS, nblk)
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (1, 10)))
    _, dense = model.prefill(params, {"tokens": toks}, cache_len=2 * PS)
    caches = paged_kv.insert_request(caches, dense, 1, np.asarray([4, 2]))
    view = paged_kv.gather_dense(caches)

    def check(v, d):
        got = v["k"][:, 1, :2 * PS]          # row 1, first 2 blocks
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(d["k"][:, 0]))
    jax.tree.map(check, view, dense,
                 is_leaf=lambda n: isinstance(n, dict) and "k" in n)


def test_build_paged_caches_rejects_non_attention_models():
    cfg = get_config("zamba2-1.2b", smoke=True)
    with pytest.raises(ValueError, match="attention"):
        paged_kv.build_paged_caches(build_model(cfg), 2, 8, PS, 2)


def test_continuous_engine_rejects_non_attention_stacks():
    for name in ("minicpm3-4b", "zamba2-1.2b"):
        with pytest.raises(ValueError, match="attention-only"):
            ContinuousEngine(get_config(name, smoke=True), None)


# ---------------------------------------------------------------------------
# the exact-stream contract: continuous == solo ServeEngine, per request
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sampler",
                         ["greedy", "topp_scan", "topp_sharded", "topp_xla"])
def test_continuous_matches_solo_streams_across_samplers(sampler):
    eng = _engine(sampler)
    cfg, params = _cfg_params()
    rng = np.random.default_rng(3)
    reqs = [_req(f"r{i}", rng.integers(0, cfg.vocab_size, s), n, 60 + i,
                 arrival=i)
            for i, (s, n) in enumerate([(4, 6), (7, 4), (4, 5)])]
    res = eng.run(reqs)
    solo = ServeEngine(cfg, params, max_len=eng.n_blocks * PS,
                       sampler=sampler, top_p=0.9)
    for r in reqs:
        ref = np.asarray(solo.generate({"tokens": jnp.asarray(r.tokens)[None]},
                                       r.max_new_tokens,
                                       jnp.asarray(r.key)))[0]
        np.testing.assert_array_equal(res["streams"][r.rid], ref, err_msg=r.rid)


def test_continuous_eos_stream_matches_solo():
    eng = _engine()
    cfg, params = _cfg_params()
    solo = ServeEngine(cfg, params, max_len=eng.n_blocks * PS,
                       sampler="greedy")
    toks = np.random.default_rng(2).integers(0, cfg.vocab_size,
                                             5).astype(np.int32)
    key = np.asarray(jax.random.PRNGKey(7))
    full = np.asarray(solo.generate({"tokens": jnp.asarray(toks)[None]}, 8,
                                    jnp.asarray(key)))[0]
    eos = int(full[2])
    ref = np.asarray(solo.generate({"tokens": jnp.asarray(toks)[None]}, 8,
                                   jnp.asarray(key), eos_id=eos))[0]
    res = eng.run([_req("e0", toks, 8, 0, eos_id=eos)])
    np.testing.assert_array_equal(res["streams"]["e0"], ref)
    assert res["streams"]["e0"][-1] == eos and len(ref) < 8


# ---------------------------------------------------------------------------
# scheduler state machine
# ---------------------------------------------------------------------------


def test_fcfs_admission_blocks_under_page_pressure():
    """A later small request must NOT bypass a blocked earlier big one."""
    eng = _engine(page_size=4, n_pages=5, max_len=12, tick_tokens=2)
    # capacity 4 pages of 4: A and B need 3 pages each, C needs 1
    reqs = [_req("A", [1, 2, 3, 4], 8, 0, arrival=0),
            _req("B", [1, 2, 3, 4], 8, 1, arrival=1),
            _req("C", [1, 2], 2, 2, arrival=1)]
    res = eng.run(reqs)
    info = res["requests"]
    assert info["A"]["admit_step"] == 0
    # B blocked on pages until A finished; C (1 page, free slot available the
    # whole time) still waits behind B — strict FCFS
    assert info["B"]["admit_step"] >= info["A"]["finish_step"]
    assert info["C"]["admit_step"] >= info["B"]["admit_step"]
    assert res["stats"]["peak_pages"] <= 4


def test_eviction_reclaims_pages_for_later_requests():
    """More total pages than the pool holds — only works with eviction."""
    eng = _engine(page_size=4, n_pages=4, max_len=12, max_batch=1,
                  tick_tokens=4)
    cfg, _ = _cfg_params()
    rng = np.random.default_rng(0)
    reqs = [_req(f"r{i}", rng.integers(0, cfg.vocab_size, 5), 6, i)
            for i in range(4)]           # 3 pages each, 12 total vs pool of 3
    res = eng.run(reqs)
    assert len(res["streams"]) == 4
    assert res["stats"]["peak_pages"] <= eng.alloc.capacity == 3
    assert all(len(s) == 6 for s in res["streams"].values())


def test_zero_length_and_over_budget_rejected_eagerly():
    eng = _engine()
    with pytest.raises(ValueError, match="zero-length"):
        eng.run([_req("z", np.zeros(0, np.int32), 2, 0)])
    with pytest.raises(ValueError, match="max_len"):
        eng.run([_req("b", np.ones(30, np.int32), 10, 0)])
    with pytest.raises(ValueError, match="max_new_tokens >= 1"):
        eng.run([_req("n", [1, 2], 0, 0)])


def test_arrival_trace_replays_deterministically():
    eng = _engine()
    cfg, _ = _cfg_params()
    reqs = poisson_trace(5, rate=0.4, vocab_size=cfg.vocab_size, seed=11,
                         prompt_len=(3, 8), max_new=(2, 5))
    r1, r2 = eng.run(reqs), eng.run(reqs)
    assert r1["stats"] == r2["stats"]
    assert r1["requests"] == r2["requests"]
    for k in r1["streams"]:
        np.testing.assert_array_equal(r1["streams"][k], r2["streams"][k])
    # and the trace itself is a pure function of the seed
    again = poisson_trace(5, rate=0.4, vocab_size=cfg.vocab_size, seed=11,
                          prompt_len=(3, 8), max_new=(2, 5))
    for a, b in zip(reqs, again):
        assert a.arrival_step == b.arrival_step
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_decode_n_stages_exactly_one_while_loop():
    """Trace-only launch guard: multi-token decode is ONE while_loop."""
    eng = _engine()
    assert count_while_loops(eng.decode_n_jaxpr(4)) == 1
    assert count_while_loops(eng.decode_n_jaxpr(eng.tick_tokens)) == 1


def test_page_budget_guard_fires_under_checks():
    from jax.experimental.checkify import JaxRuntimeError
    with guards.checks():
        eng = _engine()
        b = eng.max_batch
        bad_pos = jnp.full((b,), eng.n_blocks * PS, jnp.int32)  # past budget
        with pytest.raises(JaxRuntimeError, match="page budget"):
            eng._decode_n(eng.params, eng.caches, jnp.zeros((b,), jnp.int32),
                          bad_pos, jnp.zeros((b, 2), jnp.uint32),
                          jnp.zeros((b,), bool), jnp.ones((b,), jnp.int32),
                          jnp.full((b,), -1, jnp.int32), 2)
