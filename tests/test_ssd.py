"""Chunked SSD scan + mLSTM vs sequential references; MCScan distributed scan."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.ssd import mlstm_chunked, mlstm_ref, ssd_scan, ssd_scan_ref


@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_ssd_chunked_matches_sequential(chunk):
    rng = np.random.default_rng(chunk)
    b, s, h, p, n = 2, 100, 3, 8, 4
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal((b, s, h)) * 0.2), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32)
    y = ssd_scan(x, a, bm, cm, chunk=chunk)
    ref = ssd_scan_ref(x, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-3,
                               atol=1e-3)


def test_ssd_state_carry_and_initial_state():
    rng = np.random.default_rng(0)
    b, s, h, p, n = 1, 64, 2, 4, 4
    args = (jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32),
            jnp.asarray(-np.abs(rng.standard_normal((b, s, h)) * 0.1), jnp.float32),
            jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32),
            jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32))
    y1, st1 = ssd_scan(*args, chunk=16, return_final_state=True)
    y2, st2 = ssd_scan_ref(*args, return_final_state=True)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=1e-3,
                               atol=1e-3)
    # split the sequence in two: state handoff must reproduce the full run
    half = s // 2
    a1 = tuple(t[:, :half] for t in args)
    a2 = tuple(t[:, half:] for t in args)
    ya, sta = ssd_scan(*a1, chunk=16, return_final_state=True)
    yb = ssd_scan(*a2, chunk=16, initial_state=sta)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([ya, yb], 1)),
                               np.asarray(y2), rtol=1e-3, atol=1e-3)


def test_mlstm_chunked_matches_sequential():
    rng = np.random.default_rng(1)
    b, s, h, d = 2, 96, 3, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    ip = jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32)
    fp = jnp.asarray(rng.standard_normal((b, s, h)) + 2, jnp.float32)
    h1 = mlstm_chunked(q, k, v, ip, fp, chunk=32)
    h2 = mlstm_ref(q, k, v, ip, fp)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3,
                               atol=2e-3)


def test_mlstm_large_gates_stable():
    """Exponential input gates must not overflow (global-shift stabilisation)."""
    rng = np.random.default_rng(2)
    b, s, h, d = 1, 64, 2, 4
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    ip = jnp.asarray(rng.standard_normal((b, s, h)) * 40, jnp.float32)  # e^120!
    fp = jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32)
    out = np.asarray(mlstm_chunked(q, k, v, ip, fp, chunk=16))
    assert np.all(np.isfinite(out))
    ref = np.asarray(mlstm_ref(q, k, v, ip, fp))
    np.testing.assert_allclose(out, ref, rtol=5e-3, atol=5e-3)
