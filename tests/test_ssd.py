"""Chunked SSD scan + mLSTM vs sequential references; MCScan distributed scan."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.ssd import mlstm_chunked, mlstm_ref, ssd_scan, ssd_scan_ref

METHODS = ("vector", "matmul", "kernel", "blocked")


def _ssd_args(b, s, h, p, n, seed=0, decay=0.2):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32),
            jnp.asarray(-np.abs(rng.standard_normal((b, s, h)) * decay),
                        jnp.float32),
            jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32),
            jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32))


@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_ssd_chunked_matches_sequential(chunk):
    rng = np.random.default_rng(chunk)
    b, s, h, p, n = 2, 100, 3, 8, 4
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal((b, s, h)) * 0.2), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32)
    y = ssd_scan(x, a, bm, cm, chunk=chunk)
    ref = ssd_scan_ref(x, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-3,
                               atol=1e-3)


def test_ssd_state_carry_and_initial_state():
    rng = np.random.default_rng(0)
    b, s, h, p, n = 1, 64, 2, 4, 4
    args = (jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32),
            jnp.asarray(-np.abs(rng.standard_normal((b, s, h)) * 0.1), jnp.float32),
            jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32),
            jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, jnp.float32))
    y1, st1 = ssd_scan(*args, chunk=16, return_final_state=True)
    y2, st2 = ssd_scan_ref(*args, return_final_state=True)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), rtol=1e-3,
                               atol=1e-3)
    # split the sequence in two: state handoff must reproduce the full run
    half = s // 2
    a1 = tuple(t[:, :half] for t in args)
    a2 = tuple(t[:, half:] for t in args)
    ya, sta = ssd_scan(*a1, chunk=16, return_final_state=True)
    yb = ssd_scan(*a2, chunk=16, initial_state=sta)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([ya, yb], 1)),
                               np.asarray(y2), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("s,chunk", [
    (100, 24),     # non-divisible: 100 = 4*24 + 4 (ragged final chunk)
    (257, 32),     # prime length, many chunks
    (700, 64),     # longer sequence, ragged tail
])
def test_ssd_all_scan_methods_long_and_ragged(method, s, chunk):
    """Cross-chunk phase routed through each linear_scan method (PR 5).

    Previously only the rectangular happy path was pinned; this sweeps
    longer sequences and chunk sizes that do NOT divide the length, for all
    four methods of the rebuilt cross-chunk linear recurrence.
    """
    args = _ssd_args(2, s, 2, 4, 3, seed=s + chunk)
    y = ssd_scan(*args, chunk=chunk, scan_method=method)
    ref = ssd_scan_ref(*args)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("method", METHODS)
def test_ssd_state_handoff_each_method_ragged(method):
    """State carry across a split point, ragged chunks, per method."""
    s, half, chunk = 90, 41, 16     # both halves ragged w.r.t. the chunk
    args = _ssd_args(1, s, 2, 4, 4, seed=7)
    _, ref_state = ssd_scan_ref(*args, return_final_state=True)
    y_ref = ssd_scan_ref(*args)
    a1 = tuple(t[:, :half] for t in args)
    a2 = tuple(t[:, half:] for t in args)
    ya, sta = ssd_scan(*a1, chunk=chunk, scan_method=method,
                       return_final_state=True)
    yb, stb = ssd_scan(*a2, chunk=chunk, scan_method=method,
                       initial_state=sta, return_final_state=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([ya, yb], 1)),
                               np.asarray(y_ref), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(stb), np.asarray(ref_state),
                               rtol=2e-3, atol=2e-3)


def test_ssd_strong_decay_long_sequence_finite():
    """Deep decay over many chunks: underflowed carries flush, never NaN."""
    args = _ssd_args(1, 1024, 2, 4, 2, seed=3, decay=1.0)
    ref = np.asarray(ssd_scan_ref(*args))
    for method in METHODS:
        y = np.asarray(ssd_scan(*args, chunk=32, scan_method=method))
        assert np.all(np.isfinite(y)), method
        np.testing.assert_allclose(y, ref, rtol=5e-3, atol=5e-3)


def test_mlstm_chunked_matches_sequential():
    rng = np.random.default_rng(1)
    b, s, h, d = 2, 96, 3, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    ip = jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32)
    fp = jnp.asarray(rng.standard_normal((b, s, h)) + 2, jnp.float32)
    h1 = mlstm_chunked(q, k, v, ip, fp, chunk=32)
    h2 = mlstm_ref(q, k, v, ip, fp)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-3,
                               atol=2e-3)


def test_mlstm_large_gates_stable():
    """Exponential input gates must not overflow (global-shift stabilisation)."""
    rng = np.random.default_rng(2)
    b, s, h, d = 1, 64, 2, 4
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    ip = jnp.asarray(rng.standard_normal((b, s, h)) * 40, jnp.float32)  # e^120!
    fp = jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32)
    out = np.asarray(mlstm_chunked(q, k, v, ip, fp, chunk=16))
    assert np.all(np.isfinite(out))
    ref = np.asarray(mlstm_ref(q, k, v, ip, fp))
    np.testing.assert_allclose(out, ref, rtol=5e-3, atol=5e-3)
