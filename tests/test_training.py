"""Training substrate: optimizer vs reference, trainer convergence, checkpoint
atomicity/corruption/resume, straggler monitor, grad accumulation."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.pipeline import ByteCorpus, Prefetcher, SyntheticLM
from repro.models.model import get_config
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update, lr_at
from repro.training.straggler import StragglerConfig, StragglerMonitor
from repro.training.trainer import Trainer


def _numpy_adamw(cfg, g, m, v, p, step):
    gn = np.sqrt(np.sum(g ** 2))
    g = g * min(1.0, cfg.grad_clip / (gn + 1e-9))
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** step)
    vh = v / (1 - cfg.b2 ** step)
    lr = float(lr_at(cfg, jnp.asarray(step)))
    return p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p), m, v


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100, min_lr_frac=1.0)
    rng = np.random.default_rng(0)
    p = rng.standard_normal((4, 8)).astype(np.float32)
    params = {"w": jnp.asarray(p)}
    opt = adamw_init(params)
    pn, mn, vn = p.copy(), np.zeros_like(p), np.zeros_like(p)
    for step in range(1, 4):
        g = rng.standard_normal((4, 8)).astype(np.float32)
        params, opt, _ = adamw_update(cfg, {"w": jnp.asarray(g)}, opt, params)
        pn, mn, vn = _numpy_adamw(cfg, g, mn, vn, pn, step)
        np.testing.assert_allclose(np.asarray(params["w"]), pn, rtol=1e-5,
                                   atol=1e-6)


def test_trainer_loss_decreases_and_resumes():
    cfg = get_config("llama3-8b", smoke=True)
    src = SyntheticLM(cfg.vocab_size, 64, 4, seed=0)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40),
                     ckpt_dir=d)
        out = tr.fit(src, 20, log_every=0, ckpt_every=10)
        assert out["losses"][-1] < out["losses"][0]
        # fresh trainer resumes from step 20 checkpoint
        tr2 = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40),
                      ckpt_dir=d)
        out2 = tr2.fit(src, 22, log_every=0)
        assert len(out2["losses"]) == 2          # only steps 20,21 ran


def test_grad_accum_matches_full_batch():
    cfg = get_config("qwen3-4b", smoke=True)
    src = SyntheticLM(cfg.vocab_size, 32, 8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in src.batch_at(0).items()}
    t1 = Trainer(cfg, AdamWConfig(lr=1e-3), grad_accum=1)
    t2 = Trainer(cfg, AdamWConfig(lr=1e-3), grad_accum=4)
    s1 = t1.init_state(jax.random.PRNGKey(0))
    s2 = t2.init_state(jax.random.PRNGKey(0))
    s1, m1 = t1.train_step(s1, batch)
    s2, m2 = t2.train_step(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=2e-5)


def test_checkpoint_atomic_and_corruption_detected():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep_last=2, async_save=False)
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.int32)}}
        for step in (1, 2, 3):
            cm.save(step, tree, blocking=True)
        assert cm.all_steps() == [2, 3]          # retention policy
        rest = cm.restore(3, tree)
        np.testing.assert_array_equal(np.asarray(rest["a"]), np.asarray(tree["a"]))
        # corrupt a file -> restore must fail loudly
        ck = os.path.join(d, "ckpt_3")
        victim = [f for f in os.listdir(ck) if f.endswith(".npy")][0]
        arr = np.load(os.path.join(ck, victim))
        arr = np.asarray(arr).copy()
        arr.view(np.uint8)[0] ^= 0xFF
        np.save(os.path.join(ck, victim), arr)
        with pytest.raises(IOError, match="corruption"):
            cm.restore(3, tree)


def test_straggler_monitor_flags_slow_worker():
    mon = StragglerMonitor(StragglerConfig(min_samples=8, consecutive=3,
                                           z_threshold=3.0))
    rng = np.random.default_rng(0)
    flagged = []
    for step in range(40):
        for w in range(4):
            t = 0.1 + rng.normal(0, 0.002)
            if w == 2 and step >= 25:
                t *= 3.0                          # worker 2 degrades
            if mon.record(w, t):
                flagged.append((w, step))
    assert [w for w, _ in flagged] == [2]
    assert mon.healthy_workers([0, 1, 2, 3]) == [0, 1, 3]


def test_data_pipeline_deterministic_and_sharded():
    src = SyntheticLM(1000, 32, 8, seed=42)
    a = src.batch_at(7)
    b = src.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    s0 = src.batch_at(7, shard=0, num_shards=2)
    assert s0["tokens"].shape == (4, 32)


def test_prefetcher():
    src = SyntheticLM(100, 16, 2, seed=0)
    pf = Prefetcher(src, start_step=5)
    step, batch = pf.next()
    assert step == 5 and batch["tokens"].shape == (2, 16)
    pf.stop()


def test_byte_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"hello world, this is a tiny corpus for byte-level lm " * 20)
    src = ByteCorpus(str(p), seq_len=16, batch_size=4)
    b = src.batch_at(0)
    assert b["tokens"].shape == (4, 16)
    assert b["tokens"].max() < 256
