"""Segmented & ragged scan subsystem (ISSUE 4 acceptance contract).

Every segmented op must be bit-identical to looping the existing 1-D op over
each segment slice, for all registered methods × {fp32, bf16, int8} × ragged
segment layouts (including empty and length-1 segments); `moe_apply`'s
segmented dispatch and `ServeEngine(sampler="topp_segmented")` must produce
outputs identical to their existing paths on equivalent inputs.

Float caveat (architecture.md dispatch rule 2/6): integer paths — offsets,
permutations, counts, sampled indices — are exact unconditionally; float
*sums* are bit-identical when exactly representable (the payloads used here),
and the sampler comparisons are pinned at scales where no fp32 rounding flip
occurs (at large batch×vocab a flat packed scan can round differently from
per-row scans near a threshold).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # property tests skip (not error) in minimal environments
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

from repro.core import compress, radix_sort, scan, top_p_sample, topk
from repro.core.segmented import (
    SegmentedBatch, boundary_flags, segment_compress, segment_cumsum,
    segment_ids, segment_scan, segment_sort, segment_sums, segment_topk,
    segment_top_p_sample,
)

S = 8                        # tile side (small: interpret speed)
BT = 2                       # block_tiles for method="blocked"
METHODS_ALL = ["vector", "matmul", "kernel", "blocked"]
KW = dict(tile_s=S, block_tiles=BT)

# ragged layouts: empty segments (incl. leading/trailing/consecutive),
# length-1 segments, a segment crossing tile and block boundaries
LAYOUTS = {
    "ragged": np.asarray([0, 0, 3, 4, 4, 4, 19, 20, 33], np.int32),
    "single": np.asarray([0, 13], np.int32),
    "unit_segs": np.asarray([0, 1, 2, 3, 4], np.int32),
}

_PAYLOADS = {
    # integer-valued floats: sums exactly representable => bit-parity holds
    "float32": lambda rng, n: jnp.asarray(rng.integers(-4, 5, n), jnp.float32),
    "bfloat16": lambda rng, n: jnp.asarray(rng.integers(-4, 5, n), jnp.bfloat16),
    "int8": lambda rng, n: jnp.asarray(rng.integers(-4, 5, n), jnp.int8),
}


def _loop_segments(offsets):
    off = np.asarray(offsets)
    return [(off[i], off[i + 1]) for i in range(off.shape[0] - 1)
            if off[i + 1] > off[i]]


def _loop_scan(x, offsets, **kw):
    """Oracle: the existing 1-D scan looped over every nonempty segment."""
    outs = [np.asarray(scan(x[a:b], **kw)) for a, b in _loop_segments(offsets)]
    return np.concatenate(outs) if outs else np.zeros((0,))


# ---------------------------------------------------------------------------
# segment_scan: the acceptance parity matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS_ALL)
@pytest.mark.parametrize("dtype", list(_PAYLOADS))
@pytest.mark.parametrize("layout", list(LAYOUTS))
def test_segment_scan_parity(method, dtype, layout):
    offsets = LAYOUTS[layout]
    n = int(offsets[-1])
    x = _PAYLOADS[dtype](np.random.default_rng(n), n)
    for exclusive in (False, True):
        got = segment_scan(x, jnp.asarray(offsets), method=method,
                           exclusive=exclusive, **KW)
        want = _loop_scan(x, offsets, method=method, exclusive=exclusive, **KW)
        np.testing.assert_array_equal(np.asarray(got), want)
        assert got.dtype == jnp.asarray(want).dtype


@pytest.mark.parametrize("method", ["vector", "kernel", "blocked"])
def test_segment_scan_reverse(method):
    offsets = LAYOUTS["ragged"]
    x = jnp.asarray(np.random.default_rng(5).integers(-3, 4, 33), jnp.int32)
    got = segment_scan(x, jnp.asarray(offsets), method=method, reverse=True,
                       **KW)
    want = _loop_scan(x, offsets, method=method, reverse=True, **KW)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("method", METHODS_ALL)
def test_segment_scan_batched_leading_dims(method):
    """(B, n) payloads share the offsets — the MoE one-hot layout."""
    offsets = LAYOUTS["ragged"]
    xb = jnp.asarray(np.random.default_rng(6).integers(0, 2, (5, 33)), jnp.int8)
    got = np.asarray(segment_scan(xb, jnp.asarray(offsets), method=method,
                                  exclusive=True, **KW))
    for r in range(xb.shape[0]):
        want = _loop_scan(xb[r], offsets, method=method, exclusive=True, **KW)
        np.testing.assert_array_equal(got[r], want)


def test_segment_scan_long_input_crosses_blocks():
    """n >> block_len: the segmented phase-2 carry scan actually engages."""
    rng = np.random.default_rng(7)
    n = 4 * BT * S * S + 11                   # several blocks + ragged tail
    cuts = np.sort(rng.integers(0, n + 1, 6))
    offsets = np.concatenate([[0], cuts, [n]]).astype(np.int32)
    x = jnp.asarray(rng.integers(-3, 4, n), jnp.int32)
    want = _loop_scan(x, offsets, method="vector")
    for method in ("kernel", "blocked"):
        got = segment_scan(x, jnp.asarray(offsets), method=method, **KW)
        np.testing.assert_array_equal(np.asarray(got), want)


def test_all_empty_batch_every_op():
    """n == 0 with num_segments > 0: every op returns its documented zeros."""
    sb = SegmentedBatch.from_ragged([[], []])
    assert segment_scan(sb.values, sb.offsets).shape == (0,)
    np.testing.assert_array_equal(
        np.asarray(segment_sums(sb.values, sb.offsets)), [0, 0])
    z, c = segment_compress(sb.values, jnp.zeros((0,), bool), sb.offsets)
    assert z.shape == (0,) and np.asarray(c).tolist() == [0, 0]
    v, i = segment_sort(sb.values, sb.offsets)
    assert v.shape == (0,) and i.shape == (0,)
    tv, ti, tc = segment_topk(sb.values, sb.offsets, k=2, fill_value=-1)
    assert tv.shape == (2, 2) and np.all(np.asarray(ti) == -1)
    assert np.asarray(tc).tolist() == [0, 0]
    tok = segment_top_p_sample(sb.values.astype(jnp.float32), sb.offsets,
                               jax.random.PRNGKey(0))
    assert np.asarray(tok).tolist() == [0, 0]


def test_segment_scan_validation_and_empty():
    x = jnp.arange(4, dtype=jnp.int32)
    with pytest.raises(ValueError):
        segment_scan(x, jnp.asarray([0, 4]), method="cube")
    with pytest.raises(ValueError):
        segment_scan(x)                        # offsets required
    out = segment_scan(jnp.zeros((0,), jnp.int8), jnp.asarray([0, 0, 0]))
    assert out.shape == (0,) and out.dtype == jnp.int32


# ---------------------------------------------------------------------------
# container + boundary structure
# ---------------------------------------------------------------------------


def test_segmented_batch_roundtrip_and_pytree():
    segs = [[1, 2, 3], [], [4], [], [5, 6]]
    sb = SegmentedBatch.from_ragged(segs)
    assert sb.num_segments == 5
    assert sb.lengths.tolist() == [3, 0, 1, 0, 2]
    assert [s.tolist() for s in sb.to_ragged()] == segs
    # pytree: survives jit boundaries
    out = jax.jit(lambda b: SegmentedBatch(b.values * 2, b.offsets))(sb)
    assert isinstance(out, SegmentedBatch)
    assert np.asarray(out.values).tolist() == [2, 4, 6, 8, 10, 12]
    dense, mask = sb.to_dense(fill_value=-1)
    assert dense.shape == (5, 3)
    np.testing.assert_array_equal(dense[0], [1, 2, 3])
    np.testing.assert_array_equal(mask.sum(axis=1), [3, 0, 1, 0, 2])


def test_boundary_flags_and_segment_ids():
    offsets = jnp.asarray([0, 0, 3, 4, 4, 6], jnp.int32)
    np.testing.assert_array_equal(np.asarray(boundary_flags(offsets, 6)),
                                  [1, 0, 0, 1, 1, 0])
    np.testing.assert_array_equal(np.asarray(segment_ids(offsets, 6)),
                                  [1, 1, 1, 2, 4, 4])
    # ids respect every method of the counting scan
    np.testing.assert_array_equal(
        np.asarray(segment_ids(offsets, 6, method="matmul", tile_s=S)),
        [1, 1, 1, 2, 4, 4])


@pytest.mark.parametrize("method", METHODS_ALL)
def test_segment_sums(method):
    offsets = LAYOUTS["ragged"]
    x = jnp.asarray(np.random.default_rng(8).integers(0, 3, 33), jnp.int8)
    got = np.asarray(segment_sums(x, jnp.asarray(offsets), method=method, **KW))
    want = [int(np.asarray(x)[a:b].astype(np.int64).sum())
            for a, b in zip(offsets[:-1], offsets[1:])]
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32


# ---------------------------------------------------------------------------
# segment_compress
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS_ALL)
@pytest.mark.parametrize("dtype", list(_PAYLOADS))
def test_segment_compress_parity(method, dtype):
    offsets = LAYOUTS["ragged"]
    rng = np.random.default_rng(9)
    x = _PAYLOADS[dtype](rng, 33)
    m = jnp.asarray(rng.random(33) < 0.4)
    z, c = segment_compress(x, m, jnp.asarray(offsets), method=method, **KW)
    want_z, want_c = [], []
    for i in range(offsets.shape[0] - 1):
        a, b = offsets[i], offsets[i + 1]
        if b > a:
            zi, ci = compress(x[a:b], m[a:b], method=method, tile_s=S)
            want_z.append(np.asarray(zi))
            want_c.append(int(ci))
        else:
            want_c.append(0)
    np.testing.assert_array_equal(np.asarray(z), np.concatenate(want_z))
    np.testing.assert_array_equal(np.asarray(c), want_c)


def test_segment_compress_edge_masks():
    offsets = jnp.asarray([0, 2, 5], jnp.int32)
    x = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
    z, c = segment_compress(x, jnp.zeros(5, bool), offsets, fill_value=-7)
    np.testing.assert_array_equal(np.asarray(z), [-7] * 5)
    assert np.asarray(c).tolist() == [0, 0]
    z, c = segment_compress(x, jnp.ones(5, bool), offsets)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(x))
    assert np.asarray(c).tolist() == [2, 3]


# ---------------------------------------------------------------------------
# segment_sort / segment_topk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", METHODS_ALL)
@pytest.mark.parametrize("k", [1, 4, 8])
def test_segment_sort_parity_fp32(method, k):
    """Arbitrary (non-integer) keys: offsets are exact, so parity is exact."""
    offsets = LAYOUTS["ragged"]
    x = jnp.asarray(np.random.default_rng(10).standard_normal(33), jnp.float32)
    v, i = segment_sort(x, jnp.asarray(offsets), method=method,
                        bits_per_pass=k, **KW)
    want_v, want_i = [], []
    for a, b in _loop_segments(offsets):
        vv, ii = radix_sort(x[a:b], method=method, bits_per_pass=k, tile_s=S)
        want_v.append(np.asarray(vv))
        want_i.append(np.asarray(ii) + a)
    np.testing.assert_array_equal(np.asarray(v), np.concatenate(want_v))
    np.testing.assert_array_equal(np.asarray(i), np.concatenate(want_i))


@pytest.mark.parametrize("dtype", ["bfloat16", "int8"])
@pytest.mark.parametrize("method", METHODS_ALL)
def test_segment_sort_dtypes_descending(dtype, method):
    offsets = LAYOUTS["ragged"]
    rng = np.random.default_rng(11)
    x = (jnp.asarray(rng.integers(-128, 128, 33), jnp.int8) if dtype == "int8"
         else jnp.asarray(rng.standard_normal(33), jnp.bfloat16))
    v, i = segment_sort(x, jnp.asarray(offsets), descending=True,
                        method=method, **KW)
    xs = np.asarray(x.astype(jnp.float32))
    for a, b in _loop_segments(offsets):
        seg = np.asarray(v.astype(jnp.float32))[a:b]
        np.testing.assert_array_equal(seg, np.sort(xs[a:b], kind="stable")[::-1])
    np.testing.assert_array_equal(xs[np.asarray(i)],
                                  np.asarray(v.astype(jnp.float32)))
    with pytest.raises(ValueError):
        segment_sort(x, jnp.asarray(offsets), bits_per_pass=0)


@pytest.mark.parametrize("method", ["vector", "kernel"])
def test_segment_topk_parity(method):
    offsets = LAYOUTS["ragged"]
    x = jnp.asarray(np.random.default_rng(12).standard_normal(33), jnp.float32)
    k = 3
    v, i, c = segment_topk(x, jnp.asarray(offsets), k=k, method=method, **KW)
    assert v.shape == (8, k) and i.shape == (8, k) and c.shape == (8,)
    for s_, (a, b) in enumerate(zip(offsets[:-1], offsets[1:])):
        kk = min(k, b - a)
        assert int(c[s_]) == kk
        if kk:
            tv, ti = topk(x[a:b], kk, method=method, tile_s=S)
            np.testing.assert_array_equal(np.asarray(v)[s_, :kk], np.asarray(tv))
            np.testing.assert_array_equal(np.asarray(i)[s_, :kk], np.asarray(ti))
        assert np.all(np.asarray(i)[s_, kk:] == -1)


# ---------------------------------------------------------------------------
# segment_top_p_sample: ragged nucleus sampling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["vector", "matmul", "blocked"])
def test_segment_top_p_parity_vs_loop(method):
    """Same per-segment uniforms => same sampled (segment-local) token ids."""
    offsets = np.asarray([0, 3, 4, 23, 33], np.int32)
    logits = jnp.asarray(
        np.random.default_rng(13).standard_normal(33) * 2, jnp.float32)
    u = jax.random.uniform(jax.random.PRNGKey(3), (4, 1), dtype=jnp.float32)
    got = segment_top_p_sample(logits, jnp.asarray(offsets), None, p=0.9,
                               method=method, u=u, **KW)
    want = [int(top_p_sample(logits[a:b], None, p=0.9, method=method, u=u[s_],
                             tile_s=S))
            for s_, (a, b) in enumerate(zip(offsets[:-1], offsets[1:]))]
    assert np.asarray(got).tolist() == want


def test_segment_top_p_empty_segment_and_batch_input():
    sb = SegmentedBatch.from_ragged(
        [np.asarray([0.0, 9.0]), np.asarray([], np.float32),
         np.asarray([9.0, 0.0, 0.0])])
    tok = segment_top_p_sample(sb, key=jax.random.PRNGKey(0), p=0.9, tile_s=S)
    assert np.asarray(tok).tolist() == [1, 0, 0]


# ---------------------------------------------------------------------------
# wiring: serving engine + MoE dispatch + data pipeline
# ---------------------------------------------------------------------------


def test_serving_topp_segmented_matches_topp_scan():
    from repro.models.model import get_config
    from repro.serving.engine import ServeEngine

    cfg = get_config("llama3-8b", smoke=True)
    for seed in range(3):
        logits = jnp.asarray(
            np.random.default_rng(seed).standard_normal((3, cfg.vocab_size)) * 3,
            jnp.float32)
        key = jax.random.PRNGKey(seed)
        ref = ServeEngine(cfg, None, sampler="topp_scan")._sample(logits, key)
        got = ServeEngine(cfg, None, sampler="topp_segmented")._sample(logits,
                                                                       key)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_serving_sample_packed_ragged():
    from repro.models.model import get_config
    from repro.serving.engine import ServeEngine

    cfg = get_config("llama3-8b", smoke=True)
    eng = ServeEngine(cfg, None, sampler="topp_segmented")
    rng = np.random.default_rng(14)
    segs = [rng.standard_normal(40).astype(np.float32),
            rng.standard_normal(7).astype(np.float32),
            np.asarray([0.0, 50.0, 0.0], np.float32)]
    tok = eng.sample_packed(SegmentedBatch.from_ragged(segs),
                            jax.random.PRNGKey(0))
    assert tok.shape == (3,) and tok.dtype == jnp.int32
    assert all(0 <= int(t) < len(s) for t, s in zip(tok, segs))
    assert int(tok[2]) == 1                     # all mass on one token


def test_moe_segmented_dispatch_matches_grouped():
    from repro.models.model import get_config
    from repro.models.moe import moe_apply, moe_init

    cfg = get_config("deepseek-moe-16b", smoke=True)
    params = moe_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(
        np.random.default_rng(15).standard_normal((2, 8, cfg.d_model)),
        jnp.float32)
    y_seg, aux_seg = moe_apply(params, x, cfg, dispatch_mode="segmented")
    y_grp, aux_grp = moe_apply(params, x, cfg, dispatch_mode="grouped")
    y_auto, _ = moe_apply(params, x, cfg)
    np.testing.assert_array_equal(np.asarray(y_seg), np.asarray(y_grp))
    np.testing.assert_array_equal(np.asarray(y_seg), np.asarray(y_auto))
    assert float(aux_seg) == float(aux_grp)


def test_packed_synthetic_lm():
    from repro.data.pipeline import PackedSyntheticLM, pack_ragged

    src = PackedSyntheticLM(vocab_size=64, tokens_per_batch=96, num_docs=7,
                            seed=3)
    b1, b2 = src.batch_at(5), src.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])   # deterministic
    np.testing.assert_array_equal(b1["offsets"], b2["offsets"])
    assert b1["tokens"].shape == (96,) and b1["offsets"].shape == (8,)
    off = b1["offsets"]
    assert off[0] == 0 and off[-1] == 96 and np.all(np.diff(off) >= 0)
    np.testing.assert_array_equal(
        b1["segment_ids"], np.repeat(np.arange(7), np.diff(off)))
    assert not np.array_equal(b1["tokens"], src.batch_at(6)["tokens"])
    # the packed batch feeds the subsystem directly
    sums = segment_sums(jnp.asarray(b1["tokens"]), jnp.asarray(off))
    assert int(np.asarray(sums).sum()) == int(b1["tokens"].sum())
    p = pack_ragged([[1, 2], [], [3]])
    assert p["tokens"].tolist() == [1, 2, 3]
    assert p["offsets"].tolist() == [0, 2, 2, 3]
    assert p["segment_ids"].tolist() == [0, 0, 2]


# ---------------------------------------------------------------------------
# launch-count guards (mirrors the multisplit jaxpr guard)
# ---------------------------------------------------------------------------


def _count_pallas_launches(fn, substr, *args) -> int:
    def walk(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                nm = eqn.params.get("name_and_src_info",
                                    eqn.params.get("name", ""))
                if substr in str(nm):
                    total += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    total += walk(v.jaxpr)
                elif hasattr(v, "eqns"):
                    total += walk(v)
        return total

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def test_segment_scan_kernel_launch_counts():
    x = jnp.asarray(np.random.default_rng(16).integers(0, 3, 3 * BT * S * S),
                    jnp.int32)
    offsets = jnp.asarray([0, 5, 3 * BT * S * S], jnp.int32)
    got = _count_pallas_launches(
        lambda v, o: segment_scan(v, o, method="kernel", **KW), "segscan_mm",
        x, offsets)
    assert got == 1                 # the whole segmented scan is one launch
    got = _count_pallas_launches(
        lambda v, o: segment_scan(v, o, method="blocked", **KW),
        "segscan_pipeline", x, offsets)
    assert got == 3                 # summaries + segmented carry + fused 1+3


# ---------------------------------------------------------------------------
# property-based (hypothesis): random ragged layouts vs the loop oracle
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 9), min_size=1, max_size=60),
           st.lists(st.integers(0, 60), min_size=0, max_size=6),
           st.sampled_from(["vector", "matmul"]))
    def test_segment_scan_property(values, cuts, method):
        x = jnp.asarray(values, jnp.int32)
        n = x.shape[0]
        offsets = np.concatenate(
            [[0], np.sort(np.clip(cuts, 0, n)), [n]]).astype(np.int32)
        got = segment_scan(x, jnp.asarray(offsets), method=method, **KW)
        want = _loop_scan(x, offsets, method=method, **KW)
        np.testing.assert_array_equal(np.asarray(got), want)
        # segment totals recompose to the global total
        sums = segment_sums(x, jnp.asarray(offsets), method=method, **KW)
        assert int(np.asarray(sums).sum()) == int(np.asarray(x).sum())

else:  # pragma: no cover

    @pytest.mark.skip(reason="hypothesis not installed — property tests skipped")
    def test_segment_scan_property_placeholder():
        pass  # visible placeholder so missing hypothesis shows as a skip
