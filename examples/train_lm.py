"""End-to-end training driver: train an LM on synthetic structured data with
checkpointing + resume, then generate from it with the scan-based sampler.

    PYTHONPATH=src python examples/train_lm.py --steps 200          # ~10M params
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300   # real box

Re-running the same command resumes from the latest checkpoint (restart-safe
pipeline) — kill it mid-run to see fault tolerance in action.
"""
import argparse

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.models.model import build_model
from repro.serving.engine import ServeEngine
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import Trainer


def make_cfg(size: str) -> ModelConfig:
    if size == "100m":
        return ModelConfig(name="lm-100m", family="decoder", n_layers=12,
                           d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                           vocab_size=4096, dtype="float32", remat=False)
    return ModelConfig(name="lm-10m", family="decoder", n_layers=8,
                       d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                       vocab_size=1024, dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=["10m", "100m"], default="10m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = make_cfg(args.size)
    model = build_model(cfg)
    n = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
    print(f"[example] {cfg.name}: {n / 1e6:.1f}M params")

    src = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
    tr = Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=20,
                                  total_steps=args.steps),
                 ckpt_dir=args.ckpt_dir)
    out = tr.fit(src, args.steps, log_every=20, ckpt_every=50)
    print(f"[example] loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")

    eng = ServeEngine(cfg, out["state"]["params"], max_len=args.seq + 32,
                      top_p=0.9, sampler="topp_scan")
    prompt = src.batch_at(10_000)["tokens"][:2, :16]
    gen = eng.generate({"tokens": jax.numpy.asarray(prompt)}, 16,
                       jax.random.PRNGKey(1))
    print("[example] prompt tail :", prompt[:, -6:])
    print("[example] generation  :", np.asarray(gen)[:, :12])


if __name__ == "__main__":
    main()
