"""Quickstart: the paper's matmul scan as a drop-in cumsum + scan-based operators.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import scan, radix_sort, compress, topk, top_p_sample
from repro.kernels import scan_kernel

# 1) prefix sum on the MXU: scan(z) = A@U + L^-@A@1  (paper Eq. 1)
x = jnp.asarray(np.random.default_rng(0).standard_normal(100_000), jnp.float32)
y_auto = scan(x)                            # method="auto": the committed
                                            # tuning table picks the path
y_mm = scan(x, method="matmul", variant="scanul1", tile_s=128)
y_vec = scan(x, method="vector")            # the vector-unit baseline
print("matmul scan == cumsum:", bool(jnp.allclose(y_mm, y_vec, atol=1e-2)))
print("auto scan == cumsum:  ", bool(jnp.allclose(y_auto, y_vec, atol=1e-2)))

# 2) int8 mask scan (the cube unit's int8->int32 path)
mask = jnp.asarray(np.random.default_rng(1).random(10_000) < 0.3, jnp.int8)
positions = scan(mask, exclusive=True)      # destination offsets, int32
print("mask scan dtype:", positions.dtype, "n_true:", int(positions[-1] + mask[-1]))

# 3) the fused Pallas TPU kernel (interpret=True on CPU)
y_k = scan_kernel(x[:16384], s=128)
print("pallas kernel matches:", bool(jnp.allclose(y_k, y_vec[:16384], atol=1e-2)))

# 3b) the paper's §4 blocked multi-core pipeline (three Pallas grid phases:
#     parallel block partial scans + block-sum carry scan + fused carry add)
y_b = scan(x, method="blocked", tile_s=128, block_tiles=4)
print("blocked pipeline matches:", bool(jnp.allclose(y_b, y_vec, atol=1e-2)))

# 4) scan-based operators (paper §5)
vals = jnp.asarray(np.random.default_rng(2).standard_normal(4096), jnp.float16)
sorted_vals, order = radix_sort(vals, descending=True)
print("radix sort descending head:", np.asarray(sorted_vals[:4]))
kept, count = compress(vals, vals > 0)
print("compress kept", int(count), "of", vals.shape[0])
tv, ti = topk(vals, 5)
print("top-5:", np.asarray(tv))

# 5) nucleus sampling exactly as in the paper's Llama3 case study
logits = jnp.asarray(np.random.default_rng(3).standard_normal((2, 1000)) * 2,
                     jnp.float32)
toks = top_p_sample(logits, jax.random.PRNGKey(0), p=0.9)
print("top-p samples:", np.asarray(toks))
