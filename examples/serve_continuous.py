"""Continuous batching: paged KV cache + FCFS scheduler + in-graph decode.

A seeded Poisson trace of ragged requests is served by ``ContinuousEngine``
(fixed page pool, strict-FCFS admission, one ``lax.while_loop`` per decode
tick), then each stream is checked against a solo ``ServeEngine.generate``
call with the same per-request PRNG key — the exact-stream contract.

    PYTHONPATH=src python examples/serve_continuous.py --requests 6
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import build_model, get_config
from repro.serving.engine import ServeEngine
from repro.serving.scheduler import (ContinuousEngine, count_while_loops,
                                     poisson_trace)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-batch", type=int, default=3)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--n-pages", type=int, default=13)
    ap.add_argument("--rate", type=float, default=0.5)
    ap.add_argument("--sampler", default="greedy",
                    choices=ContinuousEngine.SAMPLERS)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)   # reduced config on CPU
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = ContinuousEngine(cfg, params, max_batch=args.max_batch,
                           page_size=args.page_size, n_pages=args.n_pages,
                           max_len=32, sampler=args.sampler, tick_tokens=4)
    print(f"[serve] decode_n while_loops: "
          f"{count_while_loops(eng.decode_n_jaxpr())} (must be 1)")

    trace = poisson_trace(args.requests, rate=args.rate,
                          vocab_size=cfg.vocab_size, seed=17,
                          prompt_len=(3, 10), max_new=(2, 8))
    t0 = time.perf_counter()
    res = eng.run(trace)
    dt = time.perf_counter() - t0
    st = res["stats"]
    print(f"[serve] {st['reqs']} requests, {st['total_tokens']} tokens in "
          f"{dt:5.2f}s over {st['steps']} virtual steps / {st['ticks']} "
          f"ticks; peak pages {st['peak_pages']}/{st['pool_capacity']} "
          f"(util {st['peak_util']:.0%})")
    for rid, info in res["requests"].items():
        print(f"[serve]   {rid}: arrived {info['arrival_step']:3d} admitted "
              f"{info['admit_step']:3d} finished {info['finish_step']:3d} "
              f"({info['n_tokens']} tokens)")

    # exact-stream contract: continuous == solo dense, per request
    solo = ServeEngine(cfg, params, max_len=eng.n_blocks * args.page_size,
                       sampler=args.sampler)
    for r in trace:
        ref = np.asarray(solo.generate(
            {"tokens": jnp.asarray(r.tokens)[None]}, r.max_new_tokens,
            jnp.asarray(r.key)))[0]
        assert np.array_equal(res["streams"][r.rid], ref), r.rid
    print(f"[serve] all {len(trace)} continuous streams bitwise match their "
          "solo ServeEngine decode")


if __name__ == "__main__":
    main()
