"""The paper's operator zoo on realistic AI-workload shapes: MoE dispatch offsets
via int8 mask scan, radix-sort-based top-k, weighted sampling, compress.

    PYTHONPATH=src python examples/scan_operators.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import scan, split, compress, topk, weighted_sample

rng = np.random.default_rng(0)

# --- MoE dispatch: position-in-expert = exclusive int8 mask scan (paper Fig. 9) ---
T, E = 8192, 64
expert_of = jnp.asarray(rng.integers(0, E, T), jnp.int32)
onehot = (expert_of[:, None] == jnp.arange(E)[None, :]).astype(jnp.int8)
pos_in_expert = scan(onehot, axis=0, exclusive=True)          # int32, on the MXU
my_pos = jnp.take_along_axis(pos_in_expert, expert_of[:, None], 1)[:, 0]
print("MoE dispatch: max position-in-expert =", int(my_pos.max()),
      "(~T/E =", T // E, ")")

# --- token filtering (compress == masked_select) ---
scores = jnp.asarray(rng.standard_normal(T), jnp.float32)
kept, n = compress(scores, scores > 1.0)
print(f"compress: kept {int(n)}/{T} tokens above threshold")

# --- vocabulary top-k via descending radix sort (fp16 => 16 scan passes) ---
logits = jnp.asarray(rng.standard_normal(4096), jnp.float16)
v, i = topk(logits, 8)
print("top-8 logits:", np.asarray(v))

# --- weighted sampling by inverse transform on the scanned CDF ---
w = jnp.asarray(rng.random(100_000), jnp.float32)
keys = jax.random.split(jax.random.PRNGKey(0), 8)
samples = jax.vmap(lambda k: weighted_sample(w, k))(keys)
print("weighted samples (support 100k):", np.asarray(samples))

# --- stable split keeps relative order (the radix-sort building block) ---
x = jnp.arange(10, dtype=jnp.float32)
z, ind, nt = split(x, x % 3 == 0)
print("split([0..9], %3==0):", np.asarray(z).astype(int), "n_true =", int(nt))

# --- the same split as ONE fused Pallas launch (interpret mode off-TPU) ---
zk, indk, ntk = split(x, x % 3 == 0, method="kernel")
assert np.array_equal(np.asarray(z), np.asarray(zk))
print("split(method='kernel') matches — mask scan + scatter fused in VMEM")

# --- segmented subsystem: the same operators over a packed ragged batch ---
from repro.core import (SegmentedBatch, segment_cumsum, segment_sort,
                        segment_topk, segment_top_p_sample)

docs = [rng.standard_normal(n).astype(np.float32) for n in (5, 0, 3, 9)]
sb = SegmentedBatch.from_ragged(docs)
print("packed batch:", sb.num_segments, "segments, lengths",
      np.asarray(sb.lengths))
print("per-segment cumsum (carry resets at boundaries):",
      np.asarray(segment_cumsum(sb)).round(2))
sv, sperm = segment_sort(sb, bits_per_pass=4)       # radix sort per segment
print("segment_sort head:", np.asarray(sv[:5]).round(2))
tv, ti, tc = segment_topk(sb, k=2)
print("per-segment top-2:", np.asarray(tv).round(2), "counts", np.asarray(tc))

# ragged nucleus sampling: one launch, no padding to the longest row
tok = segment_top_p_sample(sb.values * 3, sb.offsets, jax.random.PRNGKey(1),
                           p=0.9)
print("segment_top_p_sample tokens (segment-local):", np.asarray(tok))
