"""Batched serving with the paper's scan-based top-p sampler (paper §6.5).

    PYTHONPATH=src python examples/serve_topp.py --batch 4 --new-tokens 12
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import ShapeConfig
from repro.models.model import build_model, get_config, synth_batch
from repro.serving.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)   # reduced config on CPU
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = synth_batch(cfg, ShapeConfig("serve", args.prompt_len, args.batch,
                                         "prefill"), jax.random.PRNGKey(1))
    for sampler in ("topp_scan", "topp_kernel", "topp_blocked",
                    "topp_segmented", "topp_xla", "greedy"):
        eng = ServeEngine(cfg, params, max_len=args.prompt_len +
                          args.new_tokens + cfg.n_img_tokens,
                          top_p=0.9, sampler=sampler)
        t0 = time.perf_counter()
        toks = eng.generate(batch, args.new_tokens, jax.random.PRNGKey(2))
        dt = time.perf_counter() - t0
        print(f"[serve] {sampler:10s} {np.asarray(toks).shape} in {dt:5.2f}s "
              f"-> {np.asarray(toks)[0, :8]}")


if __name__ == "__main__":
    main()
